//! The pipeline model: stages, registers, cycle-accurate streaming.

use super::signal::{sig, SignalMap, Value};
use crate::cost::UnitLibrary;
use crate::fixed::Fx;

/// Combinational blocks a stage may contain, for delay/area accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    /// LUT fetch with the given entry count.
    Lut(u32),
    /// Adder of the given width.
    Add(u32),
    /// Multiplier of the given operand width.
    Mul(u32),
    /// Squarer of the given operand width.
    Square(u32),
    /// 2:1 or 4:1 mux network (width).
    Mux(u32),
    /// Barrel shifter / leading-zero count (width).
    Shift(u32),
}

/// One pipeline stage: a named combinational function between registers.
pub struct Stage {
    /// Stage name (shows up in traces and delay reports).
    pub name: String,
    /// Blocks on this stage's combinational path (delay = max of blocks
    /// in parallel branches is approximated by the max block delay; the
    /// dominant block model matches how the paper discusses frequency).
    pub blocks: Vec<BlockKind>,
    /// The combinational function.
    pub f: Box<dyn Fn(&SignalMap) -> SignalMap + Send + Sync>,
}

impl Stage {
    /// Builds a stage.
    pub fn new(
        name: impl Into<String>,
        blocks: Vec<BlockKind>,
        f: impl Fn(&SignalMap) -> SignalMap + Send + Sync + 'static,
    ) -> Stage {
        Stage { name: name.into(), blocks, f: Box::new(f) }
    }

    /// Critical delay of this stage under a unit library (FO4).
    pub fn delay(&self, lib: &UnitLibrary) -> f64 {
        self.blocks
            .iter()
            .map(|b| match *b {
                BlockKind::Lut(entries) => lib.lut_delay(entries),
                BlockKind::Add(w) => lib.adder_delay(w),
                BlockKind::Mul(w) => lib.mult_delay(w),
                BlockKind::Square(w) => lib.mult_delay(w) * 0.8,
                BlockKind::Mux(w) => lib.mux2_ge_per_bit.log2().max(1.0) + (w as f64).log2() * 0.1,
                BlockKind::Shift(w) => 1.0 + (w.max(2) as f64).log2(),
            })
            .fold(0.0, f64::max)
    }
}

/// Result of streaming a batch through the pipeline.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// One output per input, in order.
    pub outputs: Vec<Fx>,
    /// Total cycles from first issue to last retire.
    pub cycles: usize,
    /// Peak number of in-flight items (== pipeline depth when saturated).
    pub peak_in_flight: usize,
}

/// A pipelined datapath: input adapter → stages → output extractor.
pub struct Pipeline {
    /// Descriptive name, e.g. `pwl/fig3`.
    pub name: String,
    stages: Vec<Stage>,
    /// Injects the scalar input into the first register bank.
    input: Box<dyn Fn(Fx) -> SignalMap + Send + Sync>,
    /// Extracts the scalar result from the last register bank.
    output: &'static str,
}

impl Pipeline {
    /// Builds a pipeline from stages plus input/output adapters.
    pub fn new(
        name: impl Into<String>,
        input: impl Fn(Fx) -> SignalMap + Send + Sync + 'static,
        stages: Vec<Stage>,
        output: &'static str,
    ) -> Pipeline {
        assert!(!stages.is_empty());
        Pipeline { name: name.into(), stages, input: Box::new(input), output }
    }

    /// Pipeline depth in cycles.
    pub fn latency(&self) -> usize {
        self.stages.len()
    }

    /// Stage names (for reports).
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.name.as_str()).collect()
    }

    /// Per-stage delays under a unit library; the max is the critical
    /// path that sets the clock.
    pub fn stage_delays(&self, lib: &UnitLibrary) -> Vec<f64> {
        self.stages.iter().map(|s| s.delay(lib)).collect()
    }

    /// Critical-path delay (FO4) = slowest stage.
    pub fn critical_delay(&self, lib: &UnitLibrary) -> f64 {
        self.stage_delays(lib).into_iter().fold(0.0, f64::max)
    }

    /// Single-value evaluation (runs the data through all stages).
    pub fn eval(&self, x: Fx) -> Fx {
        let mut regs = (self.input)(x);
        for stage in &self.stages {
            regs = (stage.f)(&regs);
        }
        sig(&regs, self.output).fx()
    }

    /// Cycle-accurate streaming simulation: one new input issued per
    /// cycle, every in-flight item advances one stage per cycle.
    pub fn simulate(&self, inputs: &[Fx]) -> SimResult {
        let depth = self.stages.len();
        // slots[i] = register bank feeding stage i; during a cycle every
        // stage computes from its input register and latches into the
        // next register at the cycle edge (item issued in cycle c retires
        // at the end of cycle c + depth − 1).
        let mut slots: Vec<Option<SignalMap>> = vec![None; depth];
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut next_in = 0usize;
        let mut cycles = 0usize;
        let mut peak = 0usize;
        while outputs.len() < inputs.len() {
            // Issue this cycle's input into stage 0's register.
            if next_in < inputs.len() {
                slots[0] = Some((self.input)(inputs[next_in]));
                next_in += 1;
            }
            peak = peak.max(slots.iter().filter(|s| s.is_some()).count());
            // All stages compute in parallel; latch from the back so each
            // item moves exactly one stage per cycle.
            if let Some(regs) = slots[depth - 1].take() {
                let out = (self.stages[depth - 1].f)(&regs);
                outputs.push(sig(&out, self.output).fx());
            }
            for i in (0..depth.saturating_sub(1)).rev() {
                if let Some(regs) = slots[i].take() {
                    slots[i + 1] = Some((self.stages[i].f)(&regs));
                }
            }
            cycles += 1;
        }
        SimResult { outputs, cycles, peak_in_flight: peak }
    }
}

/// Shared front-end stage: sign peel-off + domain saturation check
/// (paper §IV: "the main algorithm can be implemented for positive
/// values only"). Produces `mag`, `neg`, `sat` signals.
pub fn sign_split_input(x: Fx, domain_max: f64) -> SignalMap {
    let neg = x.is_negative();
    let mag = x.abs();
    let sat = mag.to_f64() >= domain_max;
    let mut m = SignalMap::new();
    m.insert("mag", Value::Fx(mag));
    m.insert("neg", Value::Flag(neg));
    m.insert("sat", Value::Flag(sat));
    m
}

/// Shared back-end stage function: clamp negatives to zero, apply
/// saturation and re-apply the sign (mirrors
/// [`crate::approx::eval_odd_saturating`]).
pub fn sign_merge_stage(out_fmt: crate::fixed::QFormat) -> impl Fn(&SignalMap) -> SignalMap {
    move |regs: &SignalMap| {
        let y = sig(regs, "y").fx();
        let neg = sig(regs, "neg").flag();
        let sat = sig(regs, "sat").flag();
        let y = if sat { Fx::max(out_fmt) } else { y };
        let y = if y.is_negative() { Fx::zero(out_fmt) } else { y };
        let y = if neg { y.neg() } else { y };
        let mut m = SignalMap::new();
        m.insert("y", Value::Fx(y));
        m
    }
}

/// Copies the sign/saturation control signals through a stage.
pub fn passthrough_ctl(src: &SignalMap, dst: &mut SignalMap) {
    dst.insert("neg", sig(src, "neg"));
    dst.insert("sat", sig(src, "sat"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QFormat;

    fn double_then_inc_pipeline() -> Pipeline {
        let fmt = QFormat::S3_12;
        Pipeline::new(
            "test",
            move |x| {
                let mut m = SignalMap::new();
                m.insert("v", Value::Fx(x));
                m
            },
            vec![
                Stage::new("double", vec![BlockKind::Add(16)], move |r| {
                    let v = sig(r, "v").fx();
                    let mut m = SignalMap::new();
                    m.insert("v", Value::Fx(Fx::from_raw(v.raw() * 2, fmt)));
                    m
                }),
                Stage::new("inc", vec![BlockKind::Add(16)], move |r| {
                    let v = sig(r, "v").fx();
                    let mut m = SignalMap::new();
                    m.insert("y", Value::Fx(Fx::from_raw(v.raw() + 1, fmt)));
                    m
                }),
            ],
            "y",
        )
    }

    #[test]
    fn eval_runs_all_stages() {
        let p = double_then_inc_pipeline();
        let x = Fx::from_raw(100, QFormat::S3_12);
        assert_eq!(p.eval(x).raw(), 201);
        assert_eq!(p.latency(), 2);
    }

    #[test]
    fn simulate_matches_eval_and_counts_cycles() {
        let p = double_then_inc_pipeline();
        let inputs: Vec<Fx> = (0..10).map(|i| Fx::from_raw(i, QFormat::S3_12)).collect();
        let res = p.simulate(&inputs);
        assert_eq!(res.cycles, p.latency() + inputs.len() - 1);
        assert_eq!(res.peak_in_flight, 2);
        for (x, y) in inputs.iter().zip(&res.outputs) {
            assert_eq!(y.raw(), p.eval(*x).raw());
        }
    }

    #[test]
    fn stage_delays_reflect_blocks() {
        let p = double_then_inc_pipeline();
        let lib = UnitLibrary::default();
        let delays = p.stage_delays(&lib);
        assert_eq!(delays.len(), 2);
        assert!(delays.iter().all(|d| *d > 0.0));
        assert_eq!(p.critical_delay(&lib), delays[0].max(delays[1]));
    }
}
