//! The pipeline model: stages, registers, cycle-accurate streaming.

use super::signal::{sig, SignalMap, Value};
use crate::cost::UnitLibrary;
use crate::fixed::Fx;

/// Combinational blocks a stage may contain, for delay/area accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    /// LUT fetch: entry count × stored word width in bits.
    Lut(u32, u32),
    /// Adder of the given width.
    Add(u32),
    /// Multiplier of the given operand width.
    Mul(u32),
    /// Squarer of the given operand width.
    Square(u32),
    /// 2:1 or 4:1 mux network (width).
    Mux(u32),
    /// Barrel shifter / leading-zero count (width).
    Shift(u32),
}

/// One pipeline stage: a named combinational function between registers.
pub struct Stage {
    /// Stage name (shows up in traces and delay reports).
    pub name: String,
    /// Blocks on this stage's combinational path (delay = max of blocks
    /// in parallel branches is approximated by the max block delay; the
    /// dominant block model matches how the paper discusses frequency).
    pub blocks: Vec<BlockKind>,
    /// The combinational function.
    pub f: Box<dyn Fn(&SignalMap) -> SignalMap + Send + Sync>,
}

impl Stage {
    /// Builds a stage.
    pub fn new(
        name: impl Into<String>,
        blocks: Vec<BlockKind>,
        f: impl Fn(&SignalMap) -> SignalMap + Send + Sync + 'static,
    ) -> Stage {
        Stage { name: name.into(), blocks, f: Box::new(f) }
    }

    /// GE area of this stage: its combinational blocks plus the
    /// register bank it latches into (sized by the widest block).
    pub fn area(&self, lib: &UnitLibrary) -> f64 {
        let blocks: f64 = self.blocks.iter().map(|b| b.area(lib)).sum();
        let reg_w = self.blocks.iter().map(|b| b.width()).max().unwrap_or(16);
        blocks + lib.reg_ge_per_bit * reg_w.max(1) as f64
    }

    /// Critical delay of this stage under a unit library (FO4).
    pub fn delay(&self, lib: &UnitLibrary) -> f64 {
        self.blocks
            .iter()
            .map(|b| match *b {
                BlockKind::Lut(entries, _) => lib.lut_delay(entries),
                BlockKind::Add(w) => lib.adder_delay(w),
                BlockKind::Mul(w) => lib.mult_delay(w),
                BlockKind::Square(w) => lib.mult_delay(w) * 0.8,
                BlockKind::Mux(w) => lib.mux2_ge_per_bit.log2().max(1.0) + (w as f64).log2() * 0.1,
                BlockKind::Shift(w) => 1.0 + (w.max(2) as f64).log2(),
            })
            .fold(0.0, f64::max)
    }
}

impl BlockKind {
    /// Operand/word width in bits, for register sizing (LUTs report
    /// their stored word width).
    pub fn width(self) -> u32 {
        match self {
            BlockKind::Lut(_, bits) => bits,
            BlockKind::Add(w)
            | BlockKind::Mul(w)
            | BlockKind::Square(w)
            | BlockKind::Mux(w)
            | BlockKind::Shift(w) => w,
        }
    }

    /// GE area of this block under a unit library.
    pub fn area(self, lib: &UnitLibrary) -> f64 {
        match self {
            BlockKind::Lut(entries, bits) => lib.lut_area(entries, bits),
            BlockKind::Add(w) => lib.adder_area(w),
            BlockKind::Mul(w) => lib.mult_area(w),
            BlockKind::Square(w) => lib.squarer_area(w),
            BlockKind::Mux(w) => lib.mux2_ge_per_bit * w as f64,
            BlockKind::Shift(w) => lib.shifter_area(w),
        }
    }
}

/// Result of streaming a batch through the pipeline.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// One output per input, in order.
    pub outputs: Vec<Fx>,
    /// Total cycles from first issue to last retire.
    pub cycles: usize,
    /// Peak number of in-flight items (== pipeline depth when saturated).
    pub peak_in_flight: usize,
}

/// A pipelined datapath: input adapter → stages → output extractor.
pub struct Pipeline {
    /// Descriptive name, e.g. `pwl/fig3`.
    pub name: String,
    stages: Vec<Stage>,
    /// Injects the scalar input into the first register bank.
    input: Box<dyn Fn(Fx) -> SignalMap + Send + Sync>,
    /// Extracts the scalar result from the last register bank.
    output: &'static str,
}

impl Pipeline {
    /// Builds a pipeline from stages plus input/output adapters.
    pub fn new(
        name: impl Into<String>,
        input: impl Fn(Fx) -> SignalMap + Send + Sync + 'static,
        stages: Vec<Stage>,
        output: &'static str,
    ) -> Pipeline {
        assert!(!stages.is_empty());
        Pipeline { name: name.into(), stages, input: Box::new(input), output }
    }

    /// Pipeline depth in cycles.
    pub fn latency(&self) -> usize {
        self.stages.len()
    }

    /// Stage names (for reports).
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.name.as_str()).collect()
    }

    /// Per-stage delays under a unit library; the max is the critical
    /// path that sets the clock.
    pub fn stage_delays(&self, lib: &UnitLibrary) -> Vec<f64> {
        self.stages.iter().map(|s| s.delay(lib)).collect()
    }

    /// Critical-path delay (FO4) = slowest stage.
    pub fn critical_delay(&self, lib: &UnitLibrary) -> f64 {
        self.stage_delays(lib).into_iter().fold(0.0, f64::max)
    }

    /// Single-value evaluation (runs the data through all stages).
    pub fn eval(&self, x: Fx) -> Fx {
        let mut regs = (self.input)(x);
        for stage in &self.stages {
            regs = (stage.f)(&regs);
        }
        sig(&regs, self.output).fx()
    }

    /// Cycle-accurate streaming simulation: one new input issued per
    /// cycle, every in-flight item advances one stage per cycle (item
    /// issued in cycle c retires at the end of cycle c + depth − 1).
    /// A per-call convenience over [`Pipeline::clock`]'s single-cycle
    /// semantics — the pipeline fills and drains within this call; use
    /// [`Pipeline::feed`] to keep it warm across batches.
    pub fn simulate(&self, inputs: &[Fx]) -> SimResult {
        let mut slots: Vec<Option<SignalMap>> = vec![None; self.stages.len()];
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut next_in = 0usize;
        let mut cycles = 0usize;
        let mut peak = 0usize;
        while outputs.len() < inputs.len() {
            let issuing = next_in < inputs.len();
            // Peak is sampled post-issue, pre-retire; slot 0 is always
            // empty at a cycle boundary (clock drains it every cycle),
            // so that is the current occupancy plus this cycle's issue.
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            peak = peak.max(occupied + issuing as usize);
            let issue = if issuing {
                next_in += 1;
                Some((self.input)(inputs[next_in - 1]))
            } else {
                None
            };
            if let Some(y) = self.clock(&mut slots, issue) {
                outputs.push(y);
            }
            cycles += 1;
        }
        SimResult { outputs, cycles, peak_in_flight: peak }
    }

    /// Measured GE area: the unit library summed over every block the
    /// lowering actually instantiated, plus one register bank per
    /// stage — the hw-probe counterpart of the analytic
    /// [`crate::cost::CostModel::price`] inventory pricing.
    pub fn area_ge(&self, lib: &UnitLibrary) -> f64 {
        self.stages.iter().map(|s| s.area(lib)).sum()
    }

    /// Fresh streaming state for this pipeline (all registers empty).
    pub fn stream_state(&self) -> StreamState {
        StreamState { slots: vec![None; self.stages.len()], delivered: 0, issued: 0 }
    }

    /// One clock edge — the single definition of the latch semantics
    /// both [`Pipeline::simulate`] and [`Pipeline::feed`] run on:
    /// optionally issue into stage 0's register, retire from the last
    /// stage, advance every in-flight item one stage (latch from the
    /// back so each item moves exactly once per cycle; `slots[i]` is
    /// the register bank feeding stage i).
    fn clock(&self, slots: &mut [Option<SignalMap>], issue: Option<SignalMap>) -> Option<Fx> {
        let depth = self.stages.len();
        if let Some(regs) = issue {
            slots[0] = Some(regs);
        }
        let out = slots[depth - 1].take().map(|regs| {
            let m = (self.stages[depth - 1].f)(&regs);
            sig(&m, self.output).fx()
        });
        for i in (0..depth.saturating_sub(1)).rev() {
            if let Some(regs) = slots[i].take() {
                slots[i + 1] = Some((self.stages[i].f)(&regs));
            }
        }
        out
    }

    /// Streams one batch through persistent state, keeping the pipeline
    /// warm across calls: consecutive feeds overlap, so the next
    /// batch's issue cycles absorb this batch's drain instead of paying
    /// the fill/drain latency per batch (`simulate`'s per-call cost).
    ///
    /// Outputs are bit-exact with [`Pipeline::eval`] — stage functions
    /// are per-item, so overlap cannot change values. `cycles` is the
    /// *incremental* cycle cost of this feed: `len + latency − 1` on a
    /// cold stream, exactly `len` once warm.
    ///
    /// Mechanically, the issue phase advances the real register state
    /// one cycle per input (retires belonging to items an earlier feed
    /// already delivered are swallowed); the batch's still-in-flight
    /// tail is then drained on a *copy* of the registers to complete
    /// this call's output slice, while the live registers keep those
    /// items in flight for the next feed.
    pub fn feed(&self, st: &mut StreamState, inputs: &[Fx]) -> FeedResult {
        assert_eq!(st.slots.len(), self.stages.len(), "stream state from a different pipeline");
        if inputs.is_empty() {
            return FeedResult { outputs: Vec::new(), cycles: 0 };
        }
        let depth = self.stages.len();
        let before = st.retired_by(depth);
        let mut outputs = Vec::with_capacity(inputs.len());
        for &x in inputs {
            if let Some(y) = self.clock(&mut st.slots, Some((self.input)(x))) {
                if st.delivered > 0 {
                    st.delivered -= 1;
                } else {
                    outputs.push(y);
                }
            }
        }
        st.issued += inputs.len() as u64;
        // Speculative drain on a register copy: these cycles overlap
        // the next feed's issue phase, so they are not charged here.
        let mut ghost = st.slots.clone();
        let mut swallow = st.delivered;
        while outputs.len() < inputs.len() {
            if let Some(y) = self.clock(&mut ghost, None) {
                if swallow > 0 {
                    swallow -= 1;
                } else {
                    outputs.push(y);
                }
            }
        }
        st.delivered = st.in_flight();
        FeedResult { outputs, cycles: st.retired_by(depth) - before }
    }
}

/// Persistent streaming state for one pipeline: the register banks and
/// issue bookkeeping [`Pipeline::feed`] keeps warm across batches.
pub struct StreamState {
    /// Register banks (slot i feeds stage i), as in [`Pipeline::simulate`].
    slots: Vec<Option<SignalMap>>,
    /// In-flight items whose outputs an earlier feed already delivered
    /// via its speculative drain; their real retires are swallowed.
    delivered: usize,
    /// Total inputs issued since the stream started.
    issued: u64,
}

impl StreamState {
    /// Virtual cycle by which everything issued so far has retired:
    /// with one issue per cycle and no stalls that is
    /// `issued + depth − 1` ([`Pipeline::simulate`]'s cycle-count
    /// convention), or 0 before anything was issued.
    fn retired_by(&self, depth: usize) -> u64 {
        if self.issued == 0 {
            0
        } else {
            self.issued + depth as u64 - 1
        }
    }

    /// Number of items currently occupying pipeline registers.
    pub fn in_flight(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Total inputs issued since the stream started.
    pub fn issued(&self) -> u64 {
        self.issued
    }
}

/// What one [`Pipeline::feed`] produced.
pub struct FeedResult {
    /// One output per input, in order (bit-exact vs [`Pipeline::eval`]).
    pub outputs: Vec<Fx>,
    /// Incremental cycles this feed consumed: `len + latency − 1` on a
    /// cold stream, `len` once warm.
    pub cycles: u64,
}

/// Shared front-end stage: sign peel-off + domain saturation check
/// (paper §IV: "the main algorithm can be implemented for positive
/// values only"). Produces `mag`, `neg`, `sat` signals.
pub fn sign_split_input(x: Fx, domain_max: f64) -> SignalMap {
    let neg = x.is_negative();
    let mag = x.abs();
    let sat = mag.to_f64() >= domain_max;
    let mut m = SignalMap::new();
    m.insert("mag", Value::Fx(mag));
    m.insert("neg", Value::Flag(neg));
    m.insert("sat", Value::Flag(sat));
    m
}

/// Shared back-end stage function: clamp negatives to zero, apply
/// saturation and re-apply the sign (mirrors
/// [`crate::approx::eval_odd_saturating`]).
pub fn sign_merge_stage(out_fmt: crate::fixed::QFormat) -> impl Fn(&SignalMap) -> SignalMap {
    move |regs: &SignalMap| {
        let y = sig(regs, "y").fx();
        let neg = sig(regs, "neg").flag();
        let sat = sig(regs, "sat").flag();
        let y = if sat { Fx::max(out_fmt) } else { y };
        let y = if y.is_negative() { Fx::zero(out_fmt) } else { y };
        let y = if neg { y.neg() } else { y };
        let mut m = SignalMap::new();
        m.insert("y", Value::Fx(y));
        m
    }
}

/// Copies the sign/saturation control signals through a stage.
pub fn passthrough_ctl(src: &SignalMap, dst: &mut SignalMap) {
    dst.insert("neg", sig(src, "neg"));
    dst.insert("sat", sig(src, "sat"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QFormat;

    fn double_then_inc_pipeline() -> Pipeline {
        let fmt = QFormat::S3_12;
        Pipeline::new(
            "test",
            move |x| {
                let mut m = SignalMap::new();
                m.insert("v", Value::Fx(x));
                m
            },
            vec![
                Stage::new("double", vec![BlockKind::Add(16)], move |r| {
                    let v = sig(r, "v").fx();
                    let mut m = SignalMap::new();
                    m.insert("v", Value::Fx(Fx::from_raw(v.raw() * 2, fmt)));
                    m
                }),
                Stage::new("inc", vec![BlockKind::Add(16)], move |r| {
                    let v = sig(r, "v").fx();
                    let mut m = SignalMap::new();
                    m.insert("y", Value::Fx(Fx::from_raw(v.raw() + 1, fmt)));
                    m
                }),
            ],
            "y",
        )
    }

    #[test]
    fn eval_runs_all_stages() {
        let p = double_then_inc_pipeline();
        let x = Fx::from_raw(100, QFormat::S3_12);
        assert_eq!(p.eval(x).raw(), 201);
        assert_eq!(p.latency(), 2);
    }

    #[test]
    fn simulate_matches_eval_and_counts_cycles() {
        let p = double_then_inc_pipeline();
        let inputs: Vec<Fx> = (0..10).map(|i| Fx::from_raw(i, QFormat::S3_12)).collect();
        let res = p.simulate(&inputs);
        assert_eq!(res.cycles, p.latency() + inputs.len() - 1);
        assert_eq!(res.peak_in_flight, 2);
        for (x, y) in inputs.iter().zip(&res.outputs) {
            assert_eq!(y.raw(), p.eval(*x).raw());
        }
    }

    #[test]
    fn feed_is_bit_exact_and_amortizes_fill_latency() {
        let p = double_then_inc_pipeline();
        let inputs: Vec<Fx> = (0..10).map(|i| Fx::from_raw(i, QFormat::S3_12)).collect();
        let mut st = p.stream_state();
        // Cold feed: pays the fill latency, exactly like simulate.
        let first = p.feed(&mut st, &inputs);
        assert_eq!(first.cycles as usize, p.latency() + inputs.len() - 1);
        // Warm feeds: one cycle per element, the fill is amortized.
        let second = p.feed(&mut st, &inputs);
        assert_eq!(second.cycles as usize, inputs.len());
        let third = p.feed(&mut st, &inputs);
        assert_eq!(third.cycles as usize, inputs.len());
        // Every feed's outputs are bit-exact vs scalar eval.
        for res in [&first, &second, &third] {
            assert_eq!(res.outputs.len(), inputs.len());
            for (x, y) in inputs.iter().zip(&res.outputs) {
                assert_eq!(y.raw(), p.eval(*x).raw());
            }
        }
        // Steady-state in-flight equals pipeline depth − 1.
        assert_eq!(st.in_flight(), p.latency() - 1);
        assert_eq!(st.issued(), 3 * inputs.len() as u64);
        // Empty feeds are free.
        let nil = p.feed(&mut st, &[]);
        assert_eq!(nil.cycles, 0);
        assert!(nil.outputs.is_empty());
    }

    #[test]
    fn feed_handles_batches_smaller_than_depth() {
        // Single-element feeds through a 2-deep pipeline: every output
        // still correct, warm incremental cost is 1 cycle.
        let p = double_then_inc_pipeline();
        let mut st = p.stream_state();
        for i in 0..6i64 {
            let x = Fx::from_raw(i * 7, QFormat::S3_12);
            let res = p.feed(&mut st, &[x]);
            assert_eq!(res.outputs.len(), 1);
            assert_eq!(res.outputs[0].raw(), p.eval(x).raw(), "feed {i}");
            let want = if i == 0 { p.latency() as u64 } else { 1 };
            assert_eq!(res.cycles, want, "feed {i}");
        }
    }

    #[test]
    fn area_sums_blocks_and_registers() {
        let p = double_then_inc_pipeline();
        let lib = UnitLibrary::default();
        let want = 2.0 * (lib.adder_area(16) + lib.reg_ge_per_bit * 16.0);
        assert!((p.area_ge(&lib) - want).abs() < 1e-9);
        // Block pricing delegates to the unit library.
        assert_eq!(BlockKind::Mul(16).area(&lib), lib.mult_area(16));
        assert_eq!(BlockKind::Lut(64, 16).area(&lib), lib.lut_area(64, 16));
        // Measured LUT area scales with the stored word width (the
        // output-precision axis the explorer sweeps).
        assert!(BlockKind::Lut(64, 8).area(&lib) < BlockKind::Lut(64, 16).area(&lib));
        assert!(BlockKind::Shift(16).area(&lib) > 0.0);
        assert_eq!(BlockKind::Lut(64, 16).width(), 16);
    }

    #[test]
    fn stage_delays_reflect_blocks() {
        let p = double_then_inc_pipeline();
        let lib = UnitLibrary::default();
        let delays = p.stage_delays(&lib);
        assert_eq!(delays.len(), 2);
        assert!(delays.iter().all(|d| *d > 0.0));
        assert_eq!(p.critical_delay(&lib), delays[0].max(delays[1]));
    }
}
