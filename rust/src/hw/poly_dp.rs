//! Pipelined datapaths for the polynomial methods — the paper's Fig 3
//! block diagram ("High level Block diagram for polynomial approximation
//! methods (A, B1, B2 and C)"): input decode → LUT fetch → interpolation
//! arithmetic → output merge.
//!
//! Every stage reuses the *same* fixed-point helpers as the golden
//! `eval_fx` models, so pipeline outputs are bit-identical by
//! construction (and asserted by the module tests in [`super`]).

use super::pipeline::{
    passthrough_ctl, sign_merge_stage, sign_split_input, BlockKind, Pipeline, Stage,
};
use super::signal::{sig, SignalMap, Value};
use crate::approx::catmull_rom::{CatmullRom, INT_FMT as CR_FMT};
use crate::approx::pwl::Pwl;
use crate::approx::taylor::Taylor;
use crate::approx::TanhApprox;
use crate::fixed::{fx_mul_wide, Fx, FxWide, QFormat, Round};

/// Builds the Fig 3 pipeline for PWL (method A):
/// `fetch → delta → multiply → accumulate → sign`.
pub fn pwl_pipeline(pwl: Pwl, out: QFormat) -> Pipeline {
    let domain = pwl.domain_max();
    let lut_entries = pwl.lut().len() as u32;
    let w = out.width();
    let p1 = pwl.clone();

    let fetch = Stage::new("fetch", vec![BlockKind::Lut(lut_entries, w)], move |r| {
        let mag = sig(r, "mag").fx();
        let (idx, t) = p1.lut().split_index(mag);
        let mut m = SignalMap::new();
        m.insert("y0", Value::Fx(p1.lut().at(idx)));
        m.insert("y1", Value::Fx(p1.lut().at(idx + 1)));
        m.insert("t", Value::Fx(t));
        passthrough_ctl(r, &mut m);
        m
    });
    let delta = Stage::new("delta", vec![BlockKind::Add(w)], move |r| {
        let y0 = sig(r, "y0").fx();
        let y1 = sig(r, "y1").fx();
        let mut m = SignalMap::new();
        m.insert("y0", Value::Fx(y0));
        m.insert("delta", Value::Fx(Fx::from_raw(y1.raw() - y0.raw(), y0.format())));
        m.insert("t", sig(r, "t"));
        passthrough_ctl(r, &mut m);
        m
    });
    let mul = Stage::new("multiply", vec![BlockKind::Mul(w)], move |r| {
        let delta = sig(r, "delta").fx();
        let t = sig(r, "t").fx();
        let mut m = SignalMap::new();
        m.insert("prod", Value::Wide(fx_mul_wide(delta, t)));
        m.insert("y0", sig(r, "y0"));
        passthrough_ctl(r, &mut m);
        m
    });
    let acc = Stage::new("accumulate", vec![BlockKind::Add(w)], move |r| {
        let y0 = sig(r, "y0").fx();
        let prod = sig(r, "prod").wide();
        let y = FxWide::from_fx(y0).add(prod).narrow(out, Round::NearestEven);
        let mut m = SignalMap::new();
        m.insert("y", Value::Fx(y));
        passthrough_ctl(r, &mut m);
        m
    });
    let sign = Stage::new("sign", vec![BlockKind::Mux(w)], sign_merge_stage(out));

    Pipeline::new(
        "pwl/fig3",
        move |x| sign_split_input(x, domain),
        vec![fetch, delta, mul, acc, sign],
        "y",
    )
}

/// Builds the Fig 3 pipeline for Taylor (methods B1/B2):
/// `fetch → coeff derive (eqs. 5-7) → Horner ×(terms−1) → sign`.
pub fn taylor_pipeline(t: Taylor, out: QFormat) -> Pipeline {
    let domain = t.domain_max();
    let lut_entries = t.lut().len() as u32;
    let terms = t.terms();
    let w = crate::approx::taylor::INT_FMT.width();
    let t1 = t.clone();
    let t2 = t.clone();

    let mut stages = Vec::new();
    stages.push(Stage::new("fetch", vec![BlockKind::Lut(lut_entries, w)], move |r| {
        let mag = sig(r, "mag").fx();
        let (idx, dx) = t1.split_fx(mag);
        let mut m = SignalMap::new();
        m.insert("anchor", Value::Fx(t1.lut().at(idx)));
        m.insert("dx", Value::Fx(dx));
        passthrough_ctl(r, &mut m);
        m
    }));
    stages.push(Stage::new(
        "coeff",
        vec![BlockKind::Square(w), BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let anchor = sig(r, "anchor").fx();
            let (tt, d1, c2, c3) = t2.coeffs_fx(anchor);
            let mut m = SignalMap::new();
            m.insert("T", Value::Fx(tt));
            m.insert("d1", Value::Fx(d1));
            m.insert("c2", Value::Fx(c2));
            m.insert("c3", Value::Fx(c3));
            m.insert("dx", sig(r, "dx"));
            passthrough_ctl(r, &mut m);
            m
        },
    ));
    if terms == 4 {
        stages.push(Stage::new(
            "horner3",
            vec![BlockKind::Mul(w), BlockKind::Add(w)],
            move |r| {
                let dx = sig(r, "dx").fx();
                let acc = Taylor::horner_step(dx, sig(r, "c3").fx(), sig(r, "c2").fx());
                let mut m = SignalMap::new();
                m.insert("acc", Value::Fx(acc));
                m.insert("T", sig(r, "T"));
                m.insert("d1", sig(r, "d1"));
                m.insert("dx", sig(r, "dx"));
                passthrough_ctl(r, &mut m);
                m
            },
        ));
    }
    let first_key: &'static str = if terms == 4 { "acc" } else { "c2" };
    stages.push(Stage::new(
        "horner2",
        vec![BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let dx = sig(r, "dx").fx();
            let acc = Taylor::horner_step(dx, sig(r, first_key).fx(), sig(r, "d1").fx());
            let mut m = SignalMap::new();
            m.insert("acc", Value::Fx(acc));
            m.insert("T", sig(r, "T"));
            m.insert("dx", sig(r, "dx"));
            passthrough_ctl(r, &mut m);
            m
        },
    ));
    stages.push(Stage::new(
        "horner1",
        vec![BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let dx = sig(r, "dx").fx();
            let y = Taylor::horner_final(dx, sig(r, "acc").fx(), sig(r, "T").fx(), out);
            let mut m = SignalMap::new();
            m.insert("y", Value::Fx(y));
            passthrough_ctl(r, &mut m);
            m
        },
    ));
    stages.push(Stage::new("sign", vec![BlockKind::Mux(out.width())], sign_merge_stage(out)));

    let name = if terms == 3 { "taylor-quadratic/fig3" } else { "taylor-cubic/fig3" };
    Pipeline::new(name, move |x| sign_split_input(x, domain), stages, "y")
}

/// Builds the Fig 3 pipeline for Catmull-Rom (method C):
/// `fetch(P_{k−1}…P_{k+2}) → t-vector → MAC → sign`.
pub fn catmull_rom_pipeline(cr: CatmullRom, out: QFormat) -> Pipeline {
    let domain = cr.domain_max();
    let lut_entries = cr.lut().len() as u32;
    let w = CR_FMT.width();
    let c1 = cr.clone();

    let fetch = Stage::new("fetch", vec![BlockKind::Lut(lut_entries, w)], move |r| {
        let mag = sig(r, "mag").fx();
        let (idx, t) = c1.lut().split_index(mag);
        let k = idx as isize;
        let mut m = SignalMap::new();
        m.insert("p0", Value::Fx(c1.p(k - 1)));
        m.insert("p1", Value::Fx(c1.p(k)));
        m.insert("p2", Value::Fx(c1.p(k + 1)));
        m.insert("p3", Value::Fx(c1.p(k + 2)));
        m.insert("t", Value::Fx(t));
        passthrough_ctl(r, &mut m);
        m
    });
    let tvec = Stage::new(
        "t-vector",
        vec![BlockKind::Square(w), BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let t = sig(r, "t").fx();
            let b = CatmullRom::basis_fx(t);
            let mut m = SignalMap::new();
            m.insert("b0", Value::Fx(b[0]));
            m.insert("b1", Value::Fx(b[1]));
            m.insert("b2", Value::Fx(b[2]));
            m.insert("b3", Value::Fx(b[3]));
            for key in ["p0", "p1", "p2", "p3"] {
                m.insert(key, sig(r, key));
            }
            passthrough_ctl(r, &mut m);
            m
        },
    );
    let mac = Stage::new(
        "mac",
        vec![BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let b = [sig(r, "b0").fx(), sig(r, "b1").fx(), sig(r, "b2").fx(), sig(r, "b3").fx()];
            let p = [sig(r, "p0").fx(), sig(r, "p1").fx(), sig(r, "p2").fx(), sig(r, "p3").fx()];
            let mut acc = fx_mul_wide(b[0], p[0].convert(CR_FMT, Round::NearestEven));
            for i in 1..4 {
                acc = acc.add(fx_mul_wide(b[i], p[i].convert(CR_FMT, Round::NearestEven)));
            }
            let mut m = SignalMap::new();
            m.insert("y", Value::Fx(acc.narrow(out, Round::NearestEven)));
            passthrough_ctl(r, &mut m);
            m
        },
    );
    let sign = Stage::new("sign", vec![BlockKind::Mux(out.width())], sign_merge_stage(out));

    Pipeline::new(
        "catmull-rom/fig3",
        move |x| sign_split_input(x, domain),
        vec![fetch, tvec, mac, sign],
        "y",
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::TanhApprox;

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn pwl_pipeline_matches_golden_everywhere() {
        // Exhaustive, not sampled — PWL is cheap enough.
        let golden = Pwl::table1();
        let pipe = pwl_pipeline(golden.clone(), OUT);
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(pipe.eval(x).raw(), golden.eval_fx(x, OUT).raw(), "raw {raw}");
        }
    }

    #[test]
    fn taylor_pipeline_depth_scales_with_terms() {
        let p3 = taylor_pipeline(Taylor::table1_quadratic(), OUT);
        let p4 = taylor_pipeline(Taylor::table1_cubic(), OUT);
        assert_eq!(p4.latency(), p3.latency() + 1);
    }

    #[test]
    fn stage_names_follow_fig3() {
        let p = pwl_pipeline(Pwl::table1(), OUT);
        assert_eq!(p.stage_names(), vec!["fetch", "delta", "multiply", "accumulate", "sign"]);
    }

    #[test]
    fn cr_pipeline_handles_boundaries() {
        let golden = CatmullRom::table1();
        let pipe = catmull_rom_pipeline(golden.clone(), OUT);
        // first segment (negative-index reflection), last segment (guard
        // points), saturated region.
        for v in [-7.9, -6.0, -0.01, 0.0, 0.01, 5.99, 6.0, 7.9] {
            let x = Fx::from_f64(v, INP);
            assert_eq!(pipe.eval(x).raw(), golden.eval_fx(x, OUT).raw(), "x={v}");
        }
    }
}
