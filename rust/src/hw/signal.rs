//! Signals carried between pipeline stages.

use std::collections::HashMap;

use crate::fixed::{Fx, FxWide};

/// A value on a pipeline register: a fixed-point word, a wide
/// (pre-renormalization) MAC accumulator, or a raw control field
/// (sign/saturation flags, LUT indices, normalization exponents).
#[derive(Clone, Copy, Debug)]
pub enum Value {
    /// A fixed-point word.
    Fx(Fx),
    /// A wide accumulator (kept across MAC chains).
    Wide(FxWide),
    /// A raw integer control signal.
    Raw(i64),
    /// A single-bit control signal.
    Flag(bool),
}

impl Value {
    /// Extracts the Fx, panicking with the signal name context if the
    /// kind is wrong (a wiring bug in the datapath).
    pub fn fx(&self) -> Fx {
        match self {
            Value::Fx(v) => *v,
            other => panic!("signal is {other:?}, expected Fx"),
        }
    }

    /// Extracts a wide accumulator.
    pub fn wide(&self) -> FxWide {
        match self {
            Value::Wide(v) => *v,
            other => panic!("signal is {other:?}, expected Wide"),
        }
    }

    /// Extracts a raw integer.
    pub fn raw(&self) -> i64 {
        match self {
            Value::Raw(v) => *v,
            other => panic!("signal is {other:?}, expected Raw"),
        }
    }

    /// Extracts a flag bit.
    pub fn flag(&self) -> bool {
        match self {
            Value::Flag(v) => *v,
            other => panic!("signal is {other:?}, expected Flag"),
        }
    }
}

/// The register bank between two stages: named signals.
pub type SignalMap = HashMap<&'static str, Value>;

/// Convenience: builds a signal map from pairs (used by tests and
/// custom datapath assemblies).
#[allow(dead_code)]
pub fn signals(pairs: &[(&'static str, Value)]) -> SignalMap {
    pairs.iter().cloned().collect()
}

/// Fetches a signal, panicking with a wiring diagnostic when absent.
pub fn sig(map: &SignalMap, name: &'static str) -> Value {
    *map.get(name)
        .unwrap_or_else(|| panic!("missing signal '{name}' (present: {:?})", map.keys()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QFormat;

    #[test]
    fn typed_extraction() {
        let m = signals(&[
            ("x", Value::Fx(Fx::from_f64(0.5, QFormat::S3_12))),
            ("idx", Value::Raw(42)),
            ("neg", Value::Flag(true)),
        ]);
        assert_eq!(sig(&m, "x").fx().to_f64(), 0.5);
        assert_eq!(sig(&m, "idx").raw(), 42);
        assert!(sig(&m, "neg").flag());
    }

    #[test]
    #[should_panic(expected = "missing signal 'y'")]
    fn missing_signal_panics() {
        let m = signals(&[]);
        sig(&m, "y");
    }

    #[test]
    #[should_panic(expected = "expected Fx")]
    fn wrong_kind_panics() {
        let m = signals(&[("x", Value::Raw(1))]);
        sig(&m, "x").fx();
    }
}
