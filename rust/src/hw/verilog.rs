//! Verilog emission for the paper's datapaths — the "VLSI
//! implementation" deliverable in its native form.
//!
//! Historically this module hand-wrote RTL for the PWL datapath only,
//! which drifted from the simulated pipeline (the other five methods
//! had no emission at all, and nothing checked the hand-written text
//! against the arithmetic). It is now a thin façade over the netlist
//! subsystem: [`emit_spec`] elaborates the spec with
//! [`crate::rtl::elaborate`] — the same lowering the netlist simulator
//! and the `netlist` cost tier price — and prints it with
//! [`crate::rtl::verilog::emit`]. One printer, all six datapaths, and
//! the emission re-parses into a structurally identical netlist
//! ([`crate::rtl::verilog::parse`]).
//!
//! Specs the Fig 3/4/5 block diagrams cannot express return the hw
//! backend's own typed "unsupported" error instead of silently
//! emitting a datapath that was never simulated.

use crate::approx::pwl::Pwl;
use crate::approx::{IoSpec, MethodParams, MethodSpec, TanhApprox};
use crate::fixed::QFormat;

/// Emits structural Verilog for any supported design point. Errors
/// with the hw backend's typed "unsupported" message for specs the
/// block diagrams cannot lower.
pub fn emit_spec(spec: &MethodSpec) -> Result<String, String> {
    let design = crate::rtl::elaborate(spec)?;
    Ok(crate::rtl::verilog::emit(&design))
}

/// Compatibility wrapper for the original PWL-only entry point: emits
/// the Fig 3 PWL datapath for the given I/O formats. Now returns a
/// typed error for configurations the datapath cannot express (e.g. a
/// step that is not a reciprocal power of two) where the old emitter
/// silently produced broken index wiring.
pub fn emit_pwl(pwl: &Pwl, input: QFormat, output: QFormat) -> Result<String, String> {
    let spec = MethodSpec::new(
        MethodParams::Pwl { step: pwl.step() },
        IoSpec { input, output },
        pwl.domain_max(),
    )?;
    emit_spec(&spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::MethodId;

    #[test]
    fn all_six_table1_datapaths_emit_and_reparse() {
        for spec in MethodSpec::table1_all() {
            let v = emit_spec(&spec).expect("Table I specs emit");
            assert!(v.contains("module tanh_rtl (clk, x, y);"), "{spec}");
            assert!(v.contains("endmodule"), "{spec}");
            let design = crate::rtl::elaborate(&spec).unwrap();
            let back = crate::rtl::verilog::parse(&v).expect("own emission parses");
            assert_eq!(back, design, "{spec}: emission drifted from the netlist");
        }
    }

    #[test]
    fn pwl_wrapper_matches_emit_spec() {
        let spec = MethodSpec::table1(MethodId::Pwl);
        let via_wrapper =
            emit_pwl(&Pwl::table1(), QFormat::S3_12, QFormat::S_15).unwrap();
        assert_eq!(via_wrapper, emit_spec(&spec).unwrap());
    }

    #[test]
    fn unsupported_datapaths_error_typed_instead_of_emitting() {
        // A 9-term Taylor expansion has no Fig 3 Horner chain.
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = emit_spec(&bogus).unwrap_err();
        assert!(err.contains("unsupported by hw backend"), "{err}");

        // A non-power-of-two step has no split-index bit field.
        let err = emit_pwl(&Pwl::new(0.3, 6.0), QFormat::S3_12, QFormat::S_15)
            .unwrap_err();
        assert!(err.contains("reciprocal power of two"), "{err}");
    }

    #[test]
    fn emitted_lut_contents_match_the_golden_model() {
        // The ROM case arm for index 64 must encode quantize(tanh(1.0))
        // — the same spot-check the old hand-written emitter carried.
        let v = emit_spec(&MethodSpec::table1(MethodId::Pwl)).unwrap();
        let want = Pwl::table1().lut().at(64).raw();
        let lit = if want < 0 {
            format!("64: data = -16'sd{};", want.unsigned_abs())
        } else {
            format!("64: data = 16'sd{want};")
        };
        assert!(v.contains(&lit), "missing ROM arm '{lit}'");
    }
}
