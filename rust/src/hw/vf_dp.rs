//! Pipelined datapath for the velocity-factor method — the paper's
//! Fig 4 ("High level Block diagram for trignometric expansion method"):
//! a multiplexer-selected multiplier chain over the stored velocity
//! factors, the (F−1)/(F+1) divider, and the eq. (10) linear
//! compensation stage.

use super::pipeline::{
    passthrough_ctl, sign_merge_stage, sign_split_input, BlockKind, Pipeline, Stage,
};
use super::signal::{sig, SignalMap, Value};
use crate::approx::newton::{finish_div, normalize_den, nr_seed, nr_step, NR_ITERS};
use crate::approx::velocity::Velocity;
use crate::approx::TanhApprox;
use crate::fixed::{fx_add, fx_mul, fx_mul_wide, fx_sub, Fx, FxWide, QFormat, Round};

/// Internal format of the recovered tanh value (matches the golden
/// model's refinement stage).
const T_FMT: QFormat = QFormat::new(1, 24);

/// Builds the Fig 4 pipeline:
/// `split → vf-mul ×N → add/sub → normalize → nr-seed → nr-iter ×i →
///  recover-tanh → refine → sign`.
pub fn velocity_pipeline(v: Velocity, out: QFormat) -> Pipeline {
    let domain = v.domain_max();
    let wf = v.wide_format();
    let w = wf.width();
    let m_shift = v.threshold_shift();
    let kmax = v.kmax();
    let regs: Vec<Fx> = v.registers().to_vec();
    let v1 = v.clone();

    let mut stages: Vec<Stage> = Vec::new();

    // Split the magnitude into coarse bits (≥ θ) and residue (< θ).
    stages.push(Stage::new("split", vec![BlockKind::Shift(w)], move |r| {
        let mag = sig(r, "mag").fx();
        let (coarse, residue) = v1.split(mag);
        let mut m = SignalMap::new();
        m.insert("coarse", Value::Raw(coarse));
        m.insert("residue", Value::Raw(residue));
        m.insert("frac", Value::Raw(mag.format().frac_bits as i64));
        m.insert("F", Value::Fx(Fx::one(wf)));
        passthrough_ctl(r, &mut m);
        m
    }));

    // One mux+multiplier stage per stored register (Fig 4's chain).
    for (i, k) in (-(m_shift as i32)..=kmax).rev().enumerate() {
        let vf_i = regs[i];
        stages.push(Stage::new(
            format!("vfmul[2^{k}]"),
            vec![BlockKind::Mux(w), BlockKind::Mul(w)],
            move |r| {
                let coarse = sig(r, "coarse").raw();
                let frac = sig(r, "frac").raw() as i32;
                let f = sig(r, "F").fx();
                let bitpos = k + frac;
                let f = if bitpos >= 0 && (coarse >> bitpos) & 1 == 1 {
                    fx_mul(f, vf_i, wf, Round::NearestAway)
                } else {
                    f
                };
                let mut m = SignalMap::new();
                m.insert("F", Value::Fx(f));
                m.insert("coarse", sig(r, "coarse"));
                m.insert("residue", sig(r, "residue"));
                m.insert("frac", sig(r, "frac"));
                passthrough_ctl(r, &mut m);
                m
            },
        ));
    }

    // num = F − 1, den = F + 1 (two adders, parallel).
    stages.push(Stage::new("addsub", vec![BlockKind::Add(w)], move |r| {
        let f = sig(r, "F").fx();
        let one = Fx::one(wf);
        let mut m = SignalMap::new();
        m.insert("num", Value::Fx(fx_sub(f, one, wf, Round::NearestAway)));
        m.insert("den", Value::Fx(fx_add(f, one, wf, Round::NearestAway)));
        m.insert("residue", sig(r, "residue"));
        m.insert("frac", sig(r, "frac"));
        passthrough_ctl(r, &mut m);
        m
    }));

    // Divider front-end: leading-zero count + barrel shift.
    stages.push(Stage::new("normalize", vec![BlockKind::Shift(w)], move |r| {
        let den = sig(r, "den").fx();
        let (mant, e) = normalize_den(den);
        let mut m = SignalMap::new();
        m.insert("mant", Value::Fx(mant));
        m.insert("exp", Value::Raw(e as i64));
        m.insert("num", sig(r, "num"));
        m.insert("residue", sig(r, "residue"));
        m.insert("frac", sig(r, "frac"));
        passthrough_ctl(r, &mut m);
        m
    }));

    // NR seed + iterations (each iteration: two dependent multiplies).
    stages.push(Stage::new("nr-seed", vec![BlockKind::Mul(32), BlockKind::Add(32)], move |r| {
        let mant = sig(r, "mant").fx();
        let mut m = r.clone();
        m.insert("recip", Value::Fx(nr_seed(mant)));
        m
    }));
    for i in 0..NR_ITERS {
        stages.push(Stage::new(
            format!("nr-iter{i}"),
            vec![BlockKind::Mul(32), BlockKind::Mul(32), BlockKind::Add(32)],
            move |r| {
                let mant = sig(r, "mant").fx();
                let x = sig(r, "recip").fx();
                let mut m = r.clone();
                m.insert("recip", Value::Fx(nr_step(mant, x)));
                m
            },
        ));
    }

    // Recover T = num · recip · 2^−e (the divider back end); the golden
    // model short-circuits num == 0 to zero.
    stages.push(Stage::new("recover", vec![BlockKind::Mul(w)], move |r| {
        let num = sig(r, "num").fx();
        let recip = sig(r, "recip").fx();
        let e = sig(r, "exp").raw() as i32;
        let t = if num.raw() == 0 { Fx::zero(T_FMT) } else { finish_div(num, recip, e, T_FMT) };
        let mut m = SignalMap::new();
        m.insert("T", Value::Fx(t));
        m.insert("residue", sig(r, "residue"));
        m.insert("frac", sig(r, "frac"));
        passthrough_ctl(r, &mut m);
        m
    }));

    // eq. (10) refinement: y = T + b·(1 − T²).
    stages.push(Stage::new(
        "refine",
        vec![BlockKind::Square(w), BlockKind::Mul(w), BlockKind::Add(w)],
        move |r| {
            let t = sig(r, "T").fx();
            let residue = sig(r, "residue").raw();
            let frac = sig(r, "frac").raw() as u32;
            let b = Fx::from_raw(residue, QFormat::new(0, frac));
            let t2 = fx_mul(t, t, T_FMT, Round::NearestAway);
            let d1 = fx_sub(Fx::one(T_FMT), t2, T_FMT, Round::NearestAway);
            let y = fx_mul_wide(b, d1).add(FxWide::from_fx(t)).narrow(out, Round::NearestEven);
            let mut m = SignalMap::new();
            m.insert("y", Value::Fx(y));
            passthrough_ctl(r, &mut m);
            m
        },
    ));
    stages.push(Stage::new("sign", vec![BlockKind::Mux(out.width())], sign_merge_stage(out)));

    Pipeline::new("velocity/fig4", move |x| sign_split_input(x, domain), stages, "y")
}

#[cfg(test)]
mod tests {
    use super::*;

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn vf_pipeline_matches_golden_sampled() {
        let golden = Velocity::table1();
        let pipe = velocity_pipeline(golden.clone(), OUT);
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(131) {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(
                pipe.eval(x).raw(),
                golden.eval_fx(x, OUT).raw(),
                "raw {raw} x={}",
                x.to_f64()
            );
        }
    }

    #[test]
    fn chain_length_matches_register_count() {
        let v = Velocity::table1();
        let n = v.register_count();
        let pipe = velocity_pipeline(v, OUT);
        let vfmul_stages =
            pipe.stage_names().iter().filter(|s| s.starts_with("vfmul")).count();
        assert_eq!(vfmul_stages, n);
    }

    #[test]
    fn zero_input_yields_zero() {
        let pipe = velocity_pipeline(Velocity::table1(), OUT);
        assert_eq!(pipe.eval(Fx::zero(INP)).raw(), 0);
    }
}
