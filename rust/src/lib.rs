//! # tanh-vlsi
//!
//! Full-stack reproduction of *"Comparative Analysis of Polynomial and
//! Rational Approximations of Hyperbolic Tangent Function for VLSI
//! Implementation"* (Mahesh Chandra, NXP Semiconductors, 2020).
//!
//! The paper compares six fixed-point tanh approximations intended for
//! neural-network accelerator datapaths:
//!
//! | id | method                                   | module                 |
//! |----|------------------------------------------|------------------------|
//! | A  | piecewise-linear interpolation           | [`approx::pwl`]        |
//! | B1 | Taylor series, quadratic (3 terms)       | [`approx::taylor`]     |
//! | B2 | Taylor series, cubic (4 terms)           | [`approx::taylor`]     |
//! | C  | uniform cubic Catmull-Rom spline         | [`approx::catmull_rom`]|
//! | D  | velocity-factor trigonometric expansion  | [`approx::velocity`]   |
//! | E  | Lambert continued fraction               | [`approx::lambert`]    |
//!
//! Each method ships two evaluation paths: the scalar golden datapath
//! (`eval_fx`, format-tagged [`fixed::Fx`] ops — the auditable model you
//! read next to the paper) and a **compiled kernel**
//! ([`approx::TanhApprox::compile`] → [`approx::CompiledKernel`]): an
//! integer-only `raw → raw` batch evaluator, bit-exact against the
//! golden model and one to two orders of magnitude faster. Kernels
//! whose I/O formats fit a 16-bit (or 8-bit) lane additionally expose a
//! SWAR **packed** entry point ([`approx::CompiledKernel::eval_slice_packed`]:
//! 4×16-bit or 8×8-bit lanes per `u64` word, zero-dependency — no
//! `std::simd`), bit-exact against the scalar slice path and selected
//! automatically by the serving backend. Hot loops — the serving
//! backend and the exhaustive error sweeps — run on compiled kernels;
//! everything else uses the golden models.
//!
//! Configurations are first-class values: [`approx::MethodSpec`]
//! (module [`approx::spec`]) names any (method × parameter × I/O-format
//! × domain) design point, round-trips through a compact string grammar
//! (`pwl:step=1/64:in=s3.12:out=s.15`, `table1:<A|B1|B2|C|D|E>`), and
//! keys the process-wide compiled-kernel cache ([`approx::Registry`])
//! that the serving backend, the error sweeps and the explorer share —
//! one compile per design point per process, observable through the
//! serve metrics (`kernel_compiles` / `kernel_cache_hits`).
//!
//! On top of the approximation library the crate provides:
//!
//! - [`fixed`] — the Q-format fixed-point substrate all datapath models
//!   are built on (S3.12, S2.13, S.15, S2.5, S.7 …).
//! - [`error`] — error-analysis engine (max abs error, MSE/RMS, ulp
//!   metrics, exhaustive grid sweeps, 1-ulp parameter search) that
//!   regenerates the paper's Fig 2 and Tables I & III; exhaustive
//!   sweeps run on compiled kernels, chunked across threads with
//!   deterministic (thread-count-independent) results.
//! - [`cost`] — hardware cost model: component inventories per method
//!   (paper §IV) priced by a unit gate library into area / delay.
//! - [`hw`] — cycle-level pipelined datapath simulator for the block
//!   diagrams of Fig 3 (polynomial), Fig 4 (velocity factor) and Fig 5
//!   (continued fraction), including Table II's multi-bit VF lookup.
//! - [`rtl`] — structural netlist tier below [`hw`]: the same design
//!   points elaborated into a cell/net graph ([`rtl::Design`]) with
//!   registered stage boundaries, simulated flushed or cycle-accurate
//!   ([`rtl::simulate`]), printed as structural Verilog and re-parsed
//!   from our own emission ([`rtl::verilog`]), and priced cell by cell
//!   as the `netlist` cost tier ([`rtl::NetlistProbe`],
//!   `explore --backend hw --cost netlist`). Equivalence is pinned
//!   bit-exact: netlist == hw pipeline == golden kernel.
//! - [`runtime`] — PJRT wrapper that loads the JAX/Pallas-AOT'd HLO
//!   artifacts and executes them from rust (stubbed by
//!   [`runtime::xla_shim`] when the bindings are not linked).
//! - [`backend`] — the unified execution layer: one trait
//!   ([`backend::EvalBackend`], with typed availability and stable
//!   error codes) behind which all three execution paths live —
//!   `golden` (compiled kernels via the shared cache), `hw` (specs
//!   lowered to the cycle-accurate Fig 3/4/5 datapaths, bit-exact,
//!   streamed through warm per-spec pipelines with incremental
//!   simulated-cycle accounting), and `pjrt` (AOT graphs, cleanly
//!   `Unavailable` under the shim). Backends additionally expose
//!   client-holdable warm streams ([`backend::EvalStream`] via
//!   [`backend::open_stream`]) with explicit delay accounting — the
//!   substrate of the coordinator's streaming sessions. Everything
//!   that executes — the coordinator's workers, the CLI's `--backend`
//!   flag, sweeps, scenario replays — goes through it.
//! - [`coordinator`] — activation-accelerator service: request router
//!   over per-**spec** worker-shard pools (round-robin or
//!   least-loaded), dynamic batcher per shard, per-shard metrics with a
//!   log-bucketed latency histogram (p50/p95/p99, exact shard merge),
//!   batch fill rate, failure-kind counters and simulated-cycle
//!   aggregation, and backpressure; workers execute on any
//!   [`backend::EvalBackend`], ensured per served spec at startup.
//!   Streaming **sessions** pin warm per-session state (hw pipeline
//!   registers, LSTM cell state) to one shard for pulse-by-pulse
//!   sequence serving with delay accounting, a max-sessions cap and
//!   idle eviction, over both wire framings (see EXPERIMENTS.md
//!   §Streaming sessions).
//! - [`graph`] — typed LSTM/GRU cell dataflow graphs over specs: a
//!   small IR ([`graph::CellGraph`]) of `MethodSpec`-addressed
//!   activations (tanh, and sigmoid via `σ(x) = (1 + tanh(x/2))/2`)
//!   plus fixed-point elementwise ops with explicit `QFormat` edges;
//!   validation, tract-`ModelPatch`-style rewrite passes
//!   (sigmoid-into-tanh fusion onto shared Registry kernels, requant
//!   merging, dedup, prune — all bit-preserving), execution over any
//!   backend or the live coordinator ([`graph::run_lstm_cells`]), and
//!   f64-reference per-gate error budgets. Drives the `lstm` serve
//!   scenario (see EXPERIMENTS.md §Cell graphs).
//! - [`explore`] — design-space exploration / Pareto frontier over
//!   specs (method × parameter × output format), every frontier row
//!   addressable by its spec string. Cost columns resolve through
//!   [`backend::CostProbe`]: analytic §IV model on golden, measured
//!   off the lowered (audited) pipelines on hw — each row carries a
//!   typed `cost_source`, and the frontier axes are selectable
//!   ([`explore::Objective`], `--objectives err,cycles,area`).
//! - [`report`] — text/CSV renderers for every table and figure,
//!   pinned by golden fixtures under `rust/tests/fixtures/`.
//! - [`bench`] — self-contained benchmark harness (criterion is not
//!   available in the offline crate set), the machine-readable
//!   `BENCH_throughput.json` log (see EXPERIMENTS.md §Perf), and
//!   [`bench::scenario`]: deterministic seeded load scenarios replayed
//!   by `tanh-vlsi serve --scenario` into `BENCH_serve.json` (see
//!   EXPERIMENTS.md §Serve-load protocol), plus [`bench::stream`]:
//!   streaming-session scenarios (`stream-steady`/`-jitter`/`-many`)
//!   whose pulse replies verify bit-exact against cold golden replays.
//! - [`util`] — CLI parsing, JSON/CSV writers, PRNG, property-test
//!   runner: small substrates the offline image forces us to own.
//!
//! ## Quickstart
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath; the same
//! // code executes in examples/quickstart.rs and the unit tests.)
//! use tanh_vlsi::approx::{MethodSpec, TanhApprox};
//! use tanh_vlsi::fixed::Fx;
//!
//! // Table I configuration "A" by name — any other design point is
//! // one spec string away (e.g. "pwl:step=1/32:in=s2.13:out=s.15").
//! let spec = MethodSpec::parse("table1:A").unwrap();
//! let pwl = spec.build();
//! let x = Fx::from_f64(0.5, spec.io.input);
//! let y = pwl.eval_fx(x, spec.io.output);
//! assert!((y.to_f64() - 0.5f64.tanh()).abs() < 1e-4);
//! ```

pub mod approx;
pub mod backend;
pub mod bench;
pub mod coordinator;
pub mod cost;
pub mod error;
pub mod explore;
pub mod fixed;
pub mod graph;
pub mod hw;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Returns the crate name — used by the smoke tests.
pub fn hello() -> &'static str {
    "tanh-vlsi"
}
