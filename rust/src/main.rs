//! `tanh-vlsi` — CLI for the reproduction stack.
//!
//! ```text
//! tanh-vlsi eval    --method pwl --x 0.5          evaluate one input
//! tanh-vlsi eval    --spec pwl:step=1/32 --x 0.5   …or any design point
//! tanh-vlsi table1                                 regenerate Table I
//! tanh-vlsi table2                                 regenerate Table II
//! tanh-vlsi table3  --rows 4                       regenerate Table III
//! tanh-vlsi fig2    --csv-dir out/                 regenerate Fig 2
//! tanh-vlsi cost                                   §IV complexity report
//! tanh-vlsi sweep   --spec lambert:terms=9         exhaustive error for named specs
//! tanh-vlsi explore --stride 8                     Pareto frontier
//! tanh-vlsi serve   --requests 1000                run the coordinator
//! tanh-vlsi serve   --scenario all --shards 2      scenario load harness
//! tanh-vlsi serve   --spec pwl:step=1/32:in=s2.13 --scenario steady
//! tanh-vlsi pipeline --method lambert --x 1.0      cycle-level datapath
//! ```
//!
//! Design points are addressed by **spec strings** (`approx::spec`):
//! `<method>[:step=…|:threshold=…|:terms=…][:in=…][:out=…][:dom=…]`,
//! with `table1:<A|B1|B2|C|D|E>` shorthands. Every subcommand that
//! takes `--spec` accepts a comma-separated list and reports parse
//! failures with the grammar.

use std::sync::Arc;

use tanh_vlsi::approx::{spec, table1_suite, MethodId, MethodSpec, Registry, TanhApprox};
use tanh_vlsi::bench::scenario::{self, RunOptions, Verify, SCENARIO_NAMES};
use tanh_vlsi::bench::BenchLog;
use tanh_vlsi::coordinator::{
    Coordinator, CoordinatorConfig, GoldenBackend, GraphBackend, RoutePolicy,
};
use tanh_vlsi::cost::UnitLibrary;
use tanh_vlsi::error::measure_spec;
use tanh_vlsi::explore::{explore, explore_specs, pareto_frontier, ExploreConfig};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::hw::table1_pipeline;
use tanh_vlsi::report;
use tanh_vlsi::runtime::{ArtifactDir, EngineServer};
use tanh_vlsi::util::cli::{App, Command};
use tanh_vlsi::util::prng::Prng;

fn app() -> App {
    App {
        prog: "tanh-vlsi",
        about: "polynomial & rational tanh approximations for VLSI — paper reproduction stack",
        commands: vec![
            Command::new("eval", "evaluate tanh approximations at one input")
                .opt("method", "pwl|taylor1|taylor2|catmull|velocity|lambert|all", Some("all"))
                .opt("spec", "comma-separated design-point specs (overrides --method)", None)
                .opt("x", "input value", Some("0.5"))
                .opt("input", "input Q-format", Some("S3.12"))
                .opt("output", "output Q-format", Some("S.15")),
            Command::new("table1", "regenerate Table I (errors of selected configurations)"),
            Command::new("table2", "regenerate Table II (velocity-factor register file)"),
            Command::new("table3", "regenerate Table III (1-ulp parameters per format)")
                .opt("rows", "number of rows to compute (1-4)", Some("4"))
                .opt("ulp", "ulp budget", Some("1.0")),
            Command::new("fig2", "regenerate Fig 2 (error vs parameter, 6 panels)")
                .opt("csv-dir", "write per-panel CSVs to this directory", None),
            Command::new("cost", "regenerate §IV complexity analysis"),
            Command::new("sweep", "exhaustive error metrics for named design-point specs")
                .opt("spec", "comma-separated specs (default: the six Table I rows)", None),
            Command::new("explore", "design-space exploration / Pareto frontier")
                .opt("stride", "input-grid stride (1 = exhaustive)", Some("8"))
                .opt("outputs", "comma-separated output Q-formats to sweep", Some("S.15"))
                .opt("spec", "explore exactly these comma-separated specs instead", None),
            Command::new("pipeline", "run the cycle-level datapath for one input")
                .opt("method", "method name", Some("pwl"))
                .opt("x", "input value", Some("0.5")),
            Command::new("report", "generate the consolidated markdown report")
                .opt("out", "output file", Some("target/paper/REPORT.md"))
                .opt("spec", "comma-separated specs for a named-design-points section", None)
                .flag("quick", "skip the slow Fig 2 / exploration sections"),
            Command::new("verilog", "emit synthesizable Verilog for the PWL datapath")
                .opt("out", "output file (default: stdout)", None)
                .opt("step", "PWL step size (reciprocal power of two)", Some("0.015625")),
            Command::new("serve", "run the sharded coordinator under synthetic or scenario load")
                .opt("requests", "number of requests (legacy path, no --scenario)", Some("1000"))
                .opt("request-size", "activations per request (legacy path)", Some("64"))
                // golden = compiled integer kernels, works in every build;
                // pjrt needs artifacts + linked xla bindings.
                .opt("backend", "golden|pjrt", Some("golden"))
                .opt("batch", "compiled batch size", Some("1024"))
                .opt("scenario", "steady|bursty|zipf|flood|maxbatch|all (deterministic load)", None)
                .opt("seed", "scenario PRNG seed", Some("42"))
                .opt("scale", "scenario request-count multiplier (TANH_SMOKE=1 default: 0.1)", Some("1.0"))
                .opt("shards", "worker shards per method", Some("2"))
                .opt("route", "shard routing: rr|least-loaded", Some("rr"))
                .opt("spec", "comma-separated specs to serve (default: Table I suite)", None)
                .opt("out", "scenario report file", Some("BENCH_serve.json"))
                .flag("pace", "replay the scenario's open-loop schedule in real time"),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let (cmd, parsed) = match app.dispatch(&argv) {
        Ok(x) => x,
        Err(help_or_err) => {
            eprintln!("{help_or_err}");
            let is_help =
                argv.is_empty() || argv.iter().any(|a| a == "--help" || a == "-h" || a == "help");
            std::process::exit(if is_help { 0 } else { 2 });
        }
    };
    let result = match cmd.name {
        "eval" => cmd_eval(&parsed),
        "table1" => {
            println!("{}", report::table1::render(&report::table1::compute()));
            Ok(())
        }
        "table2" => {
            println!(
                "{}",
                report::table2::render(&tanh_vlsi::approx::velocity::Velocity::table1())
            );
            Ok(())
        }
        "table3" => cmd_table3(&parsed),
        "fig2" => cmd_fig2(&parsed),
        "cost" => {
            println!("{}", report::complexity::render());
            Ok(())
        }
        "sweep" => cmd_sweep(&parsed),
        "explore" => cmd_explore(&parsed),
        "pipeline" => cmd_pipeline(&parsed),
        "serve" => cmd_serve(&parsed),
        "verilog" => cmd_verilog(&parsed),
        "report" => cmd_report(&parsed),
        other => Err(format!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// The one method-name parser every subcommand uses: unknown names get
/// the canonical error listing all accepted spellings and the grammar.
fn parse_method(s: &str) -> Result<MethodId, String> {
    MethodId::parse_or_err(s)
}

/// Parses a comma-separated `--spec` list; failures carry the grammar.
fn parse_specs(arg: &str) -> Result<Vec<MethodSpec>, String> {
    let specs: Result<Vec<MethodSpec>, String> = arg
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| MethodSpec::parse(s).map_err(|e| format!("bad spec '{s}': {e}\n\n{}", spec::GRAMMAR)))
        .collect();
    let specs = specs?;
    if specs.is_empty() {
        return Err(format!("--spec needs at least one spec\n\n{}", spec::GRAMMAR));
    }
    Ok(specs)
}

fn cmd_eval(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let x: f64 = p.parse_or("x", 0.5)?;
    let want = x.tanh();
    // --spec evaluates arbitrary design points, each through its own
    // I/O formats; the --method path keeps the Table I formats.
    if let Some(arg) = p.get("spec") {
        println!("x = {x}   tanh(x) = {want:.9}\n");
        for s in parse_specs(arg)? {
            let m = s.build();
            let y = m.eval_fx(Fx::from_f64(x, s.io.input), s.io.output);
            println!(
                "{:44} {:>12.9}  err {:+.3e}  (raw {})",
                s.to_string(),
                y.to_f64(),
                y.to_f64() - want,
                y.raw()
            );
        }
        return Ok(());
    }
    let inp = QFormat::parse(p.get_or("input", "S3.12")).ok_or("bad input format")?;
    let out = QFormat::parse(p.get_or("output", "S.15")).ok_or("bad output format")?;
    let fx = Fx::from_f64(x, inp);
    println!("x = {x} ({} raw {})   tanh(x) = {want:.9}\n", inp, fx.raw());
    let methods: Vec<Box<dyn TanhApprox>> = match p.get_or("method", "all") {
        "all" => table1_suite(),
        name => {
            let id = parse_method(name)?;
            table1_suite().into_iter().filter(|m| m.id() == id).collect()
        }
    };
    for m in methods {
        let y = m.eval_fx(fx, out);
        println!(
            "{:28} {:>12.9}  err {:+.3e}  (raw {})",
            m.describe(),
            y.to_f64(),
            y.to_f64() - want,
            y.raw()
        );
    }
    Ok(())
}

/// `sweep`: exhaustive error metrics for named design points, through
/// the shared kernel cache.
fn cmd_sweep(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => MethodSpec::table1_all(),
    };
    let mut t = tanh_vlsi::util::table::TextTable::new(&[
        "spec", "max err", "RMS", "max ulp", "argmax", "points",
    ]);
    for s in &specs {
        let e = measure_spec(s);
        t.row(vec![
            s.to_string(),
            format!("{:.3e}", e.max_abs),
            format!("{:.3e}", e.rms),
            format!("{:.2}", e.max_ulp),
            format!("{:+.4}", e.argmax),
            e.points.to_string(),
        ]);
    }
    println!("{}", t.render());
    let stats = Registry::global().stats();
    println!(
        "kernel cache: {} compiles, {} hits ({} kernels resident)",
        stats.compiles,
        stats.hits,
        Registry::global().len()
    );
    Ok(())
}

fn cmd_table3(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let n: usize = p.parse_or("rows", 4usize)?;
    let ulp: f64 = p.parse_or("ulp", 1.0)?;
    let specs = tanh_vlsi::error::table3_rows();
    let rows: Vec<_> = specs
        .into_iter()
        .take(n.clamp(1, 4))
        .map(|s| {
            eprintln!("  computing {} -> {} ±{} ...", s.input, s.output, s.range);
            report::table3::compute_table3_row(s, ulp)
        })
        .collect();
    println!("{}", report::table3::render(&rows));
    Ok(())
}

fn cmd_fig2(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let series = report::fig2::compute();
    println!("{}", report::fig2::render(&series));
    if let Some(dir) = p.get("csv-dir") {
        report::fig2::write_csv(&series, std::path::Path::new(dir)).map_err(|e| e.to_string())?;
        println!("wrote CSVs to {dir}");
    }
    Ok(())
}

fn cmd_explore(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let stride: usize = p.parse_or("stride", 8usize)?;
    let points = match p.get("spec") {
        // Explicit design points: evaluate exactly these.
        Some(arg) => explore_specs(&parse_specs(arg)?, stride),
        None => {
            let outputs: Result<Vec<QFormat>, String> = p
                .get_or("outputs", "S.15")
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| QFormat::parse(s).ok_or_else(|| format!("bad output format '{s}'")))
                .collect();
            explore(ExploreConfig { stride, outputs: outputs?, ..Default::default() })
        }
    };
    let frontier = pareto_frontier(&points);
    println!("explored {} design points; Pareto frontier ({}):\n", points.len(), frontier.len());
    let mut t = tanh_vlsi::util::table::TextTable::new(&[
        "spec", "max err", "area (GE)", "latency", "stage FO4",
    ]);
    for pt in &frontier {
        t.row(vec![
            pt.spec.to_string(),
            format!("{:.2e}", pt.max_err),
            format!("{:.0}", pt.area_ge),
            pt.latency_cycles.to_string(),
            format!("{:.1}", pt.stage_delay_fo4),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_pipeline(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let id = parse_method(p.get_or("method", "pwl"))?;
    let x: f64 = p.parse_or("x", 0.5)?;
    let pipe = table1_pipeline(id, QFormat::S_15);
    let lib = UnitLibrary::default();
    let fx = Fx::from_f64(x, QFormat::S3_12);
    let y = pipe.eval(fx);
    println!("pipeline {}  latency {} cycles", pipe.name, pipe.latency());
    println!("stages:");
    for (name, delay) in pipe.stage_names().iter().zip(pipe.stage_delays(&lib)) {
        println!("  {name:16} {delay:5.1} FO4");
    }
    println!(
        "\ncritical stage {:.1} FO4;  eval({x}) = {} (tanh = {:.9})",
        pipe.critical_delay(&lib),
        y.to_f64(),
        x.tanh()
    );
    Ok(())
}

fn cmd_report(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let quick = p.flag("quick");
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => Vec::new(),
    };
    let opts = tanh_vlsi::report::full::ReportOptions {
        fig2: !quick,
        explore: !quick,
        specs,
        ..Default::default()
    };
    let text = tanh_vlsi::report::full::generate(opts);
    let out = p.get_or("out", "target/paper/REPORT.md");
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent).map_err(|e| e.to_string())?;
    }
    std::fs::write(out, &text).map_err(|e| e.to_string())?;
    println!("wrote {} bytes to {out}", text.len());
    Ok(())
}

fn cmd_verilog(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let step: f64 = p.parse_or("step", 1.0 / 64.0)?;
    let pwl = tanh_vlsi::approx::pwl::Pwl::new(step, 6.0);
    let text = tanh_vlsi::hw::verilog::emit_pwl(&pwl, QFormat::S3_12, QFormat::S_15);
    match p.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| e.to_string())?;
            println!("wrote {} bytes of Verilog to {path}", text.len());
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn serve_backend(
    backend_name: &str,
    batch: usize,
    specs: &[MethodSpec],
) -> Result<Arc<dyn tanh_vlsi::coordinator::ExecBackend>, String> {
    match backend_name {
        "golden" => Ok(Arc::new(GoldenBackend::for_specs(specs, batch))),
        "pjrt" => {
            if specs.iter().any(|s| *s != MethodSpec::table1(s.method_id())) {
                return Err(
                    "the pjrt backend only ships AOT graphs for the Table I specs; \
                     serve non-Table-I specs on --backend golden"
                        .to_string(),
                );
            }
            let engine = Arc::new(
                EngineServer::spawn(
                    ArtifactDir::open(ArtifactDir::default_path()).map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?,
            );
            println!("PJRT platform: {}", engine.platform());
            Ok(Arc::new(GraphBackend::load_all(engine, batch).map_err(|e| e.to_string())?))
        }
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn cmd_serve(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let batch: usize = p.parse_or("batch", 1024usize)?;
    let backend_name = p.get_or("backend", "golden");
    let shards: usize = p.parse_or("shards", 2usize)?;
    let route = RoutePolicy::parse(p.get_or("route", "rr"))
        .ok_or_else(|| format!("unknown route policy '{}' (rr|least-loaded)", p.get_or("route", "rr")))?;
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => MethodSpec::table1_all(),
    };
    let cfg = CoordinatorConfig { shards, route, specs: specs.clone(), ..Default::default() };
    let backend = serve_backend(backend_name, batch, &specs)?;
    match p.get("scenario") {
        Some(names) => cmd_serve_scenarios(p, names, backend, backend_name, batch, cfg),
        None => cmd_serve_legacy(p, backend, backend_name, cfg),
    }
}

/// Scenario mode: deterministic seeded load, replies verified against
/// the compiled golden kernels, report rows into `BENCH_serve.json`.
fn cmd_serve_scenarios(
    p: &tanh_vlsi::util::cli::Parsed,
    names_arg: &str,
    backend: Arc<dyn tanh_vlsi::coordinator::ExecBackend>,
    backend_name: &str,
    batch: usize,
    cfg: CoordinatorConfig,
) -> Result<(), String> {
    let seed: u64 = p.parse_or("seed", 42u64)?;
    // The tier-1 smoke shortens every scenario unless --scale is given.
    let scale: f64 = match p.get("scale") {
        Some(v) => v.parse().map_err(|_| format!("invalid value '{v}' for --scale"))?,
        None if std::env::var("TANH_SMOKE").is_ok() => 0.1,
        None => 1.0,
    };
    let names: Vec<&str> =
        if names_arg == "all" { SCENARIO_NAMES.to_vec() } else { vec![names_arg] };
    let verify = match backend_name {
        // Golden serving runs the same compiled kernels the verifier
        // does: any mismatch is a batching/routing bug, so demand
        // bit-exact agreement. The f32 PJRT graphs skip output
        // quantization; allow the Table I band.
        "golden" => Verify::Exact,
        _ => Verify::Tolerance(3e-4),
    };
    let opts = RunOptions { pace: p.flag("pace"), verify, ..Default::default() };
    let served: Vec<String> = cfg.specs.iter().map(|s| s.to_string()).collect();
    println!("serving {} spec(s): {}", served.len(), served.join(", "));
    let mut log = BenchLog::new();
    for name in names {
        let trace = scenario::build_trace(name, seed, batch, scale, &cfg.specs)?;
        let coord = Coordinator::start(backend.clone(), cfg.clone());
        let out = scenario::run_trace(&coord, &trace, &opts)?;
        let m = &out.metrics;
        let secs = out.wall.as_secs_f64().max(1e-9);
        println!(
            "scenario {name:8} seed {seed}: {} reqs ({} elements) in {:.3}s on \
             '{backend_name}' × {} shards/method [{:?}]",
            out.completed,
            out.elements,
            secs,
            coord.shards_per_method(),
            cfg.route,
        );
        println!(
            "  throughput {:.0} req/s, {:.2} Mact/s;  {} batches, fill {:.1}%, \
             {} backpressure retries",
            out.completed as f64 / secs,
            out.elements as f64 / secs / 1e6,
            m.batches,
            100.0 * m.fill_rate(),
            out.retries,
        );
        println!(
            "  latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {}  (mean {:.0})",
            m.p50_us(),
            m.p95_us(),
            m.p99_us(),
            m.latency_us_max(),
            m.mean_latency_us(),
        );
        match verify {
            Verify::Exact => println!(
                "  verified {}/{} replies bit-exact against the compiled golden kernels",
                out.verified, out.completed
            ),
            Verify::Tolerance(tol) => println!(
                "  verified {}/{} replies within {tol:.1e} of the golden kernels",
                out.verified, out.completed
            ),
            Verify::Off => {}
        }
        log.push_row(out.to_json(backend_name, coord.shards_per_method(), batch));
        coord.shutdown();
    }
    let stats = tanh_vlsi::approx::Registry::global().stats();
    println!(
        "\nkernel cache: {} compiles, {} hits across the run \
         (shards × scenarios share one kernel per spec)",
        stats.compiles, stats.hits
    );
    let out_path = p.get_or("out", "BENCH_serve.json");
    log.write(out_path).map_err(|e| e.to_string())?;
    let text = std::fs::read_to_string(out_path).map_err(|e| e.to_string())?;
    let rows = scenario::validate_serve_log(&text)?;
    println!("\nwrote {rows} scenario row(s) to {out_path} (schema OK)");
    Ok(())
}

/// Legacy mode: `--requests N` windowed synthetic load.
fn cmd_serve_legacy(
    p: &tanh_vlsi::util::cli::Parsed,
    backend: Arc<dyn tanh_vlsi::coordinator::ExecBackend>,
    backend_name: &str,
    cfg: CoordinatorConfig,
) -> Result<(), String> {
    let n: usize = p.parse_or("requests", 1000usize)?;
    let req_size: usize = p.parse_or("request-size", 64usize)?;
    let specs = cfg.specs.clone();
    let coord = Coordinator::start(backend, cfg);
    let mut g = Prng::new(42);
    let start = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        let spec = &specs[i % specs.len()];
        let values: Vec<f32> = (0..req_size).map(|_| g.f64_in(-6.0, 6.0) as f32).collect();
        pending.push(coord.submit_spec(spec, values).map_err(|e| e.to_string())?);
        // Drain in windows to bound memory.
        if pending.len() >= 256 {
            for rx in pending.drain(..) {
                rx.recv().map_err(|_| "reply dropped")?.outcome?;
            }
        }
    }
    for rx in pending {
        rx.recv().map_err(|_| "reply dropped")?.outcome?;
    }
    let elapsed = start.elapsed();
    let m = coord.metrics();
    println!(
        "\nserved {} requests ({} activations) in {:.3}s on '{backend_name}' × {} shards/method",
        m.requests,
        m.elements,
        elapsed.as_secs_f64(),
        coord.shards_per_method(),
    );
    println!(
        "throughput: {:.0} req/s, {:.2} Mact/s",
        m.requests as f64 / elapsed.as_secs_f64(),
        m.elements as f64 / elapsed.as_secs_f64() / 1e6
    );
    println!(
        "batches: {} (fill {:.1}%, efficiency {:.1}%)",
        m.batches,
        100.0 * m.fill_rate(),
        100.0 * m.batch_efficiency(),
    );
    println!(
        "latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {}  (mean {:.0})",
        m.p50_us(),
        m.p95_us(),
        m.p99_us(),
        m.latency_us_max(),
        m.mean_latency_us(),
    );
    coord.shutdown();
    Ok(())
}
