//! `tanh-vlsi` — CLI for the reproduction stack.
//!
//! ```text
//! tanh-vlsi eval    --method pwl --x 0.5          evaluate one input
//! tanh-vlsi eval    --spec pwl:step=1/32 --x 0.5   …or any design point
//! tanh-vlsi eval    --backend hw --x 0.5           …through any backend
//! tanh-vlsi table1                                 regenerate Table I
//! tanh-vlsi table2                                 regenerate Table II
//! tanh-vlsi table3  --rows 4                       regenerate Table III
//! tanh-vlsi fig2    --csv-dir out/                 regenerate Fig 2
//! tanh-vlsi cost                                   §IV complexity report
//! tanh-vlsi sweep   --spec lambert:terms=9         exhaustive error for named specs
//! tanh-vlsi explore --stride 8                     Pareto frontier (analytic §IV costs)
//! tanh-vlsi explore --backend hw --objectives err,cycles,area
//!                                                  …measured off the lowered pipelines
//! tanh-vlsi serve   --requests 1000                run the coordinator
//! tanh-vlsi serve   --scenario all --shards 2      scenario load harness
//! tanh-vlsi serve   --spec pwl:step=1/32:in=s2.13 --scenario steady
//! tanh-vlsi serve   --backend hw --scenario steady  cycle-accurate serving
//! tanh-vlsi serve   --scenario flood --sockets 8    …replayed over 8 real TCP
//!                                                  connections (json|binary|mixed)
//! tanh-vlsi serve   --scenario stream-steady       session-stateful pulse streaming
//! tanh-vlsi serve   --scenario lstm                whole LSTM cell steps via the
//!                                                  graph layer (fused sigmoids)
//! tanh-vlsi netcheck                               wire-protocol regression probes
//! tanh-vlsi pipeline --method lambert --x 1.0      cycle-level datapath
//! ```
//!
//! Execution is **backend-addressed** (`--backend golden|hw|pjrt` on
//! eval/serve/sweep, module [`tanh_vlsi::backend`]): the same design
//! points run on the compiled golden kernels, the cycle-accurate §IV
//! datapaths (bit-exact, with simulated cycle counts in the serve
//! metrics), or the PJRT graphs — which fail fast with a clean
//! `backend_unavailable` error when the xla bindings are not linked.
//!
//! Design points are addressed by **spec strings** (`approx::spec`):
//! `<method>[:step=…|:threshold=…|:terms=…][:in=…][:out=…][:dom=…]`,
//! with `table1:<A|B1|B2|C|D|E>` shorthands. Every subcommand that
//! takes `--spec` accepts a comma-separated list and reports parse
//! failures with the grammar.

use std::sync::Arc;

/// Default serve-scenario report path, anchored to the crate root so the
/// log lands in the same place no matter which directory the binary is
/// launched from. An explicit `--out` overrides it untouched.
const DEFAULT_SERVE_LOG: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json");

use tanh_vlsi::approx::{spec, MethodId, MethodSpec, Registry};
use tanh_vlsi::backend::{self, CostProbe, CostSource, EvalBackend};
use tanh_vlsi::bench::scenario::{self, RunOptions, Verify, SCENARIO_NAMES};
use tanh_vlsi::bench::sockets::{run_trace_sockets, Framing, SocketRunOptions};
use tanh_vlsi::bench::stream::{build_stream_plan, run_stream, run_stream_sockets};
use tanh_vlsi::bench::BenchLog;
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig, NetServer, RoutePolicy};
use tanh_vlsi::cost::UnitLibrary;
use tanh_vlsi::error::{measure_backend, measure_spec};
use tanh_vlsi::explore::{
    explore_specs_probed, pareto_frontier_by, sweep_specs, ExploreConfig, Objective,
};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::graph::{lstm_cell, optimize, run_lstm_cells, CellConfig, CellRunConfig};
use tanh_vlsi::hw::{pipeline_for, table1_pipeline};
use tanh_vlsi::report;
use tanh_vlsi::util::cli::{App, Command};
use tanh_vlsi::util::prng::Prng;

fn app() -> App {
    App {
        prog: "tanh-vlsi",
        about: "polynomial & rational tanh approximations for VLSI — paper reproduction stack",
        commands: vec![
            Command::new("eval", "evaluate tanh approximations at one input")
                .opt("method", "pwl|taylor1|taylor2|catmull|velocity|lambert|all", Some("all"))
                .opt("spec", "comma-separated design-point specs (overrides --method)", None)
                .opt("x", "input value", Some("0.5"))
                .opt("backend", "execution path: golden|hw|pjrt", Some("golden"))
                .opt("input", "input Q-format", Some("S3.12"))
                .opt("output", "output Q-format", Some("S.15")),
            Command::new("table1", "regenerate Table I (errors of selected configurations)"),
            Command::new("table2", "regenerate Table II (velocity-factor register file)"),
            Command::new("table3", "regenerate Table III (1-ulp parameters per format)")
                .opt("rows", "number of rows to compute (1-4)", Some("4"))
                .opt("ulp", "ulp budget", Some("1.0")),
            Command::new("fig2", "regenerate Fig 2 (error vs parameter, 6 panels)")
                .opt("csv-dir", "write per-panel CSVs to this directory", None),
            Command::new("cost", "regenerate §IV complexity analysis"),
            Command::new("sweep", "exhaustive error metrics for named design-point specs")
                .opt("spec", "comma-separated specs (default: the six Table I rows)", None)
                .opt("backend", "execution path to sweep through: golden|hw|pjrt", Some("golden")),
            Command::new("explore", "design-space exploration / Pareto frontier")
                .opt("stride", "input-grid stride (1 = exhaustive)", Some("8"))
                .opt("outputs", "comma-separated output Q-formats to sweep", Some("S.15"))
                .opt("spec", "explore exactly these comma-separated specs instead", None)
                // golden costs with the analytic §IV model; hw lowers
                // every point and measures depth/critical path/area off
                // the audited pipeline (rows labeled by cost source).
                .opt("backend", "cost probe: golden (analytic) | hw (measured)", Some("golden"))
                // netlist elaborates every point to its RTL cell graph
                // and prices the structure itself (summed cell area,
                // longest comb path between register ranks).
                .opt("cost", "cost tier: probe (backend-native) | netlist (elaborated RTL)", Some("probe"))
                .opt("objectives", "comma-separated Pareto axes: err|rms|area|cycles|cyc/elt|delay", Some("err,area,cycles")),
            Command::new("pipeline", "run the cycle-level datapath for one input")
                .opt("method", "method name", Some("pwl"))
                .opt("spec", "design-point spec to lower (overrides --method)", None)
                .opt("x", "input value", Some("0.5")),
            Command::new("report", "generate the consolidated markdown report")
                .opt("out", "output file", Some("target/paper/REPORT.md"))
                .opt("spec", "comma-separated specs for a named-design-points section", None)
                .flag("quick", "skip the slow Fig 2 / exploration sections"),
            Command::new("verilog", "emit structural Verilog for any supported datapath")
                .opt("out", "output file (default: stdout)", None)
                .opt("spec", "design-point spec to emit (overrides --step)", None)
                .opt("step", "PWL step size (reciprocal power of two)", Some("0.015625")),
            Command::new("serve", "run the sharded coordinator under synthetic or scenario load")
                .opt("requests", "number of requests (legacy path, no --scenario)", Some("1000"))
                .opt("request-size", "activations per request (legacy path)", Some("64"))
                // golden = compiled integer kernels, works in every
                // build; hw = cycle-accurate Fig 3/4/5 datapaths
                // (bit-exact, reports simulated cycles); pjrt needs
                // artifacts + linked xla bindings (fails fast with
                // backend_unavailable otherwise).
                .opt("backend", "golden|hw|pjrt", Some("golden"))
                .opt("batch", "compiled batch size", Some("1024"))
                .opt(
                    "scenario",
                    "steady|bursty|zipf|flood|maxbatch|lstm|stream-steady|stream-jitter|\
                     stream-many|all (deterministic load)",
                    None,
                )
                .opt("seed", "scenario PRNG seed", Some("42"))
                .opt("scale", "scenario request-count multiplier (TANH_SMOKE=1 default: 0.1)", Some("1.0"))
                .opt("shards", "worker shards per method", Some("2"))
                .opt("route", "shard routing: rr|least-loaded", Some("rr"))
                .opt("spec", "comma-separated specs to serve (default: Table I suite)", None)
                .opt("out", "scenario report file", Some(DEFAULT_SERVE_LOG))
                // 0 = classic in-process replay; N ≥ 1 starts the TCP
                // front-end and splits the trace over N real pipelined
                // connections (per-connection latency lands in the
                // conn_* report columns).
                .opt("sockets", "replay over N concurrent TCP connections (0 = in-process)", Some("0"))
                .opt("framing", "socket wire framing: json|binary|mixed", Some("mixed"))
                .flag("pace", "replay the scenario's open-loop schedule in real time"),
            Command::new("netcheck", "wire-protocol regression probes against a live server")
                .opt("batch", "compiled batch size", Some("256")),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let (cmd, parsed) = match app.dispatch(&argv) {
        Ok(x) => x,
        Err(help_or_err) => {
            eprintln!("{help_or_err}");
            let is_help =
                argv.is_empty() || argv.iter().any(|a| a == "--help" || a == "-h" || a == "help");
            std::process::exit(if is_help { 0 } else { 2 });
        }
    };
    let result = match cmd.name {
        "eval" => cmd_eval(&parsed),
        "table1" => {
            println!("{}", report::table1::render(&report::table1::compute()));
            // The measured-cost companion: §IV analytic model next to
            // the lowered-pipeline measurements (depth, critical path,
            // area, steady-state sim cycles/element).
            println!();
            println!("{}", report::table1::render_measured(&report::table1::compute_measured()));
            Ok(())
        }
        "table2" => {
            println!(
                "{}",
                report::table2::render(&tanh_vlsi::approx::velocity::Velocity::table1())
            );
            Ok(())
        }
        "table3" => cmd_table3(&parsed),
        "fig2" => cmd_fig2(&parsed),
        "cost" => {
            println!("{}", report::complexity::render());
            Ok(())
        }
        "sweep" => cmd_sweep(&parsed),
        "explore" => cmd_explore(&parsed),
        "pipeline" => cmd_pipeline(&parsed),
        "serve" => cmd_serve(&parsed),
        "netcheck" => cmd_netcheck(&parsed),
        "verilog" => cmd_verilog(&parsed),
        "report" => cmd_report(&parsed),
        other => Err(format!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// The one method-name parser every subcommand uses: unknown names get
/// the canonical error listing all accepted spellings and the grammar.
fn parse_method(s: &str) -> Result<MethodId, String> {
    MethodId::parse_or_err(s)
}

/// Parses a comma-separated `--spec` list; failures carry the grammar.
fn parse_specs(arg: &str) -> Result<Vec<MethodSpec>, String> {
    let specs: Result<Vec<MethodSpec>, String> = arg
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| MethodSpec::parse(s).map_err(|e| format!("bad spec '{s}': {e}\n\n{}", spec::GRAMMAR)))
        .collect();
    let specs = specs?;
    if specs.is_empty() {
        return Err(format!("--spec needs at least one spec\n\n{}", spec::GRAMMAR));
    }
    Ok(specs)
}

/// Resolves `eval`'s design points: `--spec` names them exactly;
/// otherwise `--method` picks Table I parameters, re-validated against
/// the requested `--input`/`--output` formats. One resolution path for
/// every backend.
fn eval_specs(p: &tanh_vlsi::util::cli::Parsed) -> Result<Vec<MethodSpec>, String> {
    if let Some(arg) = p.get("spec") {
        return parse_specs(arg);
    }
    let inp = QFormat::parse(p.get_or("input", "S3.12")).ok_or("bad input format")?;
    let out = QFormat::parse(p.get_or("output", "S.15")).ok_or("bad output format")?;
    let ids = match p.get_or("method", "all") {
        "all" => MethodId::all().to_vec(),
        name => vec![parse_method(name)?],
    };
    ids.into_iter()
        .map(|id| {
            let t = MethodSpec::table1(id);
            MethodSpec::new(t.params, tanh_vlsi::approx::IoSpec { input: inp, output: out }, t.domain)
        })
        .collect()
}

fn cmd_eval(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let x: f64 = p.parse_or("x", 0.5)?;
    let want = x.tanh();
    let specs = eval_specs(p)?;
    // One execution path for every backend (EvalBackend): golden runs
    // the compiled kernels (bit-exact vs the scalar models), hw the
    // cycle-accurate datapath (reporting its pipeline depth in
    // simulated cycles), pjrt fails fast with backend_unavailable
    // under the shim. PJRT graphs are AOT'd at a fixed shape
    // (tanh_<m>_1024); slice-based backends take a one-element slice.
    let backend_name = p.get_or("backend", "golden");
    let b = backend::by_name(backend_name, 1024)?;
    let n = b.fixed_batch().unwrap_or(1);
    println!("x = {x}   tanh(x) = {want:.9}   (backend: {backend_name})\n");
    for s in specs {
        b.ensure(&s).map_err(|e| e.to_string())?;
        let raw = Fx::from_f64(x, s.io.input).raw();
        let input = vec![raw; n];
        let mut out = vec![0i64; n];
        let stats = b.eval_raw(&s, &input, &mut out).map_err(|e| e.to_string())?;
        let y = out[0] as f64 * s.io.output.ulp();
        let cycles = if stats.sim_cycles > 0 {
            format!(", {} sim cycles", stats.sim_cycles)
        } else {
            String::new()
        };
        println!(
            "{:44} {:>12.9}  err {:+.3e}  (raw {}{cycles})",
            s.to_string(),
            y,
            y - want,
            out[0],
        );
    }
    Ok(())
}

/// `sweep`: exhaustive error metrics for named design points — through
/// the shared kernel cache by default, or through any execution
/// backend (`--backend hw` sweeps the cycle-accurate datapaths; since
/// they are bit-exact the numbers must match the golden sweep, which
/// makes this the exhaustive lowering audit).
fn cmd_sweep(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => MethodSpec::table1_all(),
    };
    let backend_name = p.get_or("backend", "golden");
    let alt_backend: Option<Arc<dyn EvalBackend>> = match backend_name {
        "golden" => None,
        // The pjrt graphs are fixed-shape (batch-sized inputs only);
        // an exhaustive grid sweep cannot stream through them.
        "pjrt" => {
            return Err(
                "sweeps are not supported on the fixed-shape pjrt backend \
                 (use --backend golden or hw)"
                    .to_string(),
            )
        }
        name => Some(backend::by_name(name, 1024)?),
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut t = tanh_vlsi::util::table::TextTable::new(&[
        "spec", "max err", "RMS", "max ulp", "argmax", "points",
    ]);
    for s in &specs {
        let e = match &alt_backend {
            None => measure_spec(s),
            Some(b) => measure_backend(s, b.as_ref(), threads)?,
        };
        t.row(vec![
            s.to_string(),
            format!("{:.3e}", e.max_abs),
            format!("{:.3e}", e.rms),
            format!("{:.2}", e.max_ulp),
            format!("{:+.4}", e.argmax),
            e.points.to_string(),
        ]);
    }
    println!("{}", t.render());
    let stats = Registry::global().stats();
    println!(
        "kernel cache: {} compiles, {} hits ({} kernels resident)",
        stats.compiles,
        stats.hits,
        Registry::global().len()
    );
    Ok(())
}

fn cmd_table3(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let n: usize = p.parse_or("rows", 4usize)?;
    let ulp: f64 = p.parse_or("ulp", 1.0)?;
    let specs = tanh_vlsi::error::table3_rows();
    let rows: Vec<_> = specs
        .into_iter()
        .take(n.clamp(1, 4))
        .map(|s| {
            eprintln!("  computing {} -> {} ±{} ...", s.input, s.output, s.range);
            report::table3::compute_table3_row(s, ulp)
        })
        .collect();
    println!("{}", report::table3::render(&rows));
    Ok(())
}

fn cmd_fig2(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let series = report::fig2::compute();
    println!("{}", report::fig2::render(&series));
    if let Some(dir) = p.get("csv-dir") {
        report::fig2::write_csv(&series, std::path::Path::new(dir)).map_err(|e| e.to_string())?;
        println!("wrote CSVs to {dir}");
    }
    Ok(())
}

fn cmd_explore(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let stride: usize = p.parse_or("stride", 8usize)?;
    let objectives = Objective::parse_list(p.get_or("objectives", "err,area,cycles"))?;
    // The cost probe: golden answers with the analytic §IV model (the
    // classic explorer), hw lowers every design point to its audited
    // Fig 3/4/5 pipeline and measures depth/critical path/area plus
    // steady-state cycles/element off the real datapath. PJRT has no
    // cost model to probe.
    let backend_name = p.get_or("backend", "golden");
    // --cost netlist overrides the backend-native probe: every design
    // point is elaborated to its RTL cell graph and priced structurally
    // (cell-summed area, longest comb path between register ranks),
    // with the netlist audited against the golden kernel first.
    let cost_tier = p.get_or("cost", "probe");
    let probe: Box<dyn CostProbe> = match (cost_tier, backend_name) {
        ("netlist", "golden" | "hw") => Box::new(tanh_vlsi::rtl::NetlistProbe::new()),
        ("probe", "golden") => Box::new(backend::GoldenBackend::new()),
        ("probe", "hw") => Box::new(backend::HwBackend::new()),
        ("probe" | "netlist", other) => {
            return Err(format!(
                "explore supports --backend golden|hw, not '{other}' (pjrt has no cost probe)"
            ))
        }
        (other, _) => {
            return Err(format!("explore supports --cost probe|netlist, not '{other}'"))
        }
    };
    let cost_name = if cost_tier == "netlist" { "netlist" } else { backend_name };
    let specs = match p.get("spec") {
        // Explicit design points: evaluate exactly these.
        Some(arg) => parse_specs(arg)?,
        None => {
            let outputs: Result<Vec<QFormat>, String> = p
                .get_or("outputs", "S.15")
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| QFormat::parse(s).ok_or_else(|| format!("bad output format '{s}'")))
                .collect();
            sweep_specs(&ExploreConfig { stride, outputs: outputs?, ..Default::default() })
        }
    };
    let points = explore_specs_probed(&specs, stride, probe.as_ref())?;
    let frontier = pareto_frontier_by(&points, &objectives);
    let measured = frontier.iter().filter(|p| p.cost_source == CostSource::Measured).count();
    let netlist = frontier.iter().filter(|p| p.cost_source == CostSource::Netlist).count();
    let names: Vec<&str> = objectives.iter().map(|o| o.name()).collect();
    println!(
        "explored {} design points on '{cost_name}' costs; Pareto frontier over ({}) \
         has {} points ({} measured, {} netlist, {} analytic):\n",
        points.len(),
        names.join(", "),
        frontier.len(),
        measured,
        netlist,
        frontier.len() - measured - netlist,
    );
    let mut t = tanh_vlsi::util::table::TextTable::new(&[
        "spec", "max err", "area (GE)", "latency", "cyc/elt", "stage FO4", "cost",
    ]);
    for pt in &frontier {
        t.row(vec![
            pt.spec.to_string(),
            format!("{:.2e}", pt.max_err),
            format!("{:.0}", pt.area_ge),
            pt.latency_cycles.to_string(),
            format!("{:.2}", pt.cycles_per_element),
            format!("{:.1}", pt.stage_delay_fo4),
            pt.cost_source.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_pipeline(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let x: f64 = p.parse_or("x", 0.5)?;
    // --spec lowers any design point the hw backend can express
    // (pipeline_for); --method keeps the Table I configuration.
    let (pipe, input_fmt) = match p.get("spec") {
        Some(arg) => {
            let s = MethodSpec::parse(arg)
                .map_err(|e| format!("bad spec '{arg}': {e}\n\n{}", spec::GRAMMAR))?;
            (pipeline_for(&s)?, s.io.input)
        }
        None => {
            let id = parse_method(p.get_or("method", "pwl"))?;
            (table1_pipeline(id, QFormat::S_15), QFormat::S3_12)
        }
    };
    let lib = UnitLibrary::default();
    let fx = Fx::from_f64(x, input_fmt);
    let y = pipe.eval(fx);
    println!("pipeline {}  latency {} cycles", pipe.name, pipe.latency());
    println!("stages:");
    for (name, delay) in pipe.stage_names().iter().zip(pipe.stage_delays(&lib)) {
        println!("  {name:16} {delay:5.1} FO4");
    }
    println!(
        "\ncritical stage {:.1} FO4;  eval({x}) = {} (tanh = {:.9})",
        pipe.critical_delay(&lib),
        y.to_f64(),
        x.tanh()
    );
    Ok(())
}

fn cmd_report(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let quick = p.flag("quick");
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => Vec::new(),
    };
    let opts = tanh_vlsi::report::full::ReportOptions {
        fig2: !quick,
        explore: !quick,
        specs,
        ..Default::default()
    };
    let text = tanh_vlsi::report::full::generate(opts);
    let out = p.get_or("out", "target/paper/REPORT.md");
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent).map_err(|e| e.to_string())?;
    }
    std::fs::write(out, &text).map_err(|e| e.to_string())?;
    println!("wrote {} bytes to {out}", text.len());
    Ok(())
}

fn cmd_verilog(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    // --spec emits any supported design point; --step keeps the
    // original PWL-only shorthand.
    let spec = match p.get("spec") {
        Some(arg) => MethodSpec::parse(arg)
            .map_err(|e| format!("bad spec '{arg}': {e}\n\n{}", spec::GRAMMAR))?,
        None => {
            let step: f64 = p.parse_or("step", 1.0 / 64.0)?;
            MethodSpec::new(
                tanh_vlsi::approx::MethodParams::Pwl { step },
                tanh_vlsi::approx::IoSpec::table1(),
                6.0,
            )?
        }
    };
    let text = tanh_vlsi::hw::verilog::emit_spec(&spec)?;
    match p.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| e.to_string())?;
            println!("wrote {} bytes of Verilog to {path}", text.len());
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_serve(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    let batch: usize = p.parse_or("batch", 1024usize)?;
    let backend_name = p.get_or("backend", "golden");
    let shards: usize = p.parse_or("shards", 2usize)?;
    let route = RoutePolicy::parse(p.get_or("route", "rr"))
        .ok_or_else(|| format!("unknown route policy '{}' (rr|least-loaded)", p.get_or("route", "rr")))?;
    let specs = match p.get("spec") {
        Some(arg) => parse_specs(arg)?,
        None => MethodSpec::table1_all(),
    };
    let mut cfg = CoordinatorConfig { shards, route, specs: specs.clone(), ..Default::default() };
    cfg.batcher.batch_elements = batch;
    // One resolution path for every backend; availability and per-spec
    // support are checked by Coordinator::start (typed
    // backend_unavailable / unknown_spec errors — `--backend pjrt`
    // under the xla shim fails fast here, before any load is sent).
    let backend = backend::by_name(backend_name, batch)?;
    match p.get("scenario") {
        Some(names) => cmd_serve_scenarios(p, names, backend, backend_name, batch, cfg),
        None => cmd_serve_legacy(p, backend, backend_name, cfg),
    }
}

/// Scenario mode: deterministic seeded load, replies verified against
/// the compiled golden kernels, report rows into `BENCH_serve.json`.
fn cmd_serve_scenarios(
    p: &tanh_vlsi::util::cli::Parsed,
    names_arg: &str,
    backend: Arc<dyn EvalBackend>,
    backend_name: &str,
    batch: usize,
    cfg: CoordinatorConfig,
) -> Result<(), String> {
    let seed: u64 = p.parse_or("seed", 42u64)?;
    // The tier-1 smoke shortens every scenario unless --scale is given.
    let scale: f64 = match p.get("scale") {
        Some(v) => v.parse().map_err(|_| format!("invalid value '{v}' for --scale"))?,
        None if std::env::var("TANH_SMOKE").is_ok() => 0.1,
        None => 1.0,
    };
    let names: Vec<&str> =
        if names_arg == "all" { SCENARIO_NAMES.to_vec() } else { vec![names_arg] };
    let verify = match backend_name {
        // Golden serving runs the same compiled kernels the verifier
        // does, and the hw datapaths are bit-exact by construction
        // (ensure audits the lowering): any mismatch is a
        // batching/routing/lowering bug, so demand bit-exact
        // agreement. The PJRT graphs compute in f32 (conversions are
        // the shared golden ones); allow the Table I band for the
        // compute-path difference.
        "golden" | "hw" => Verify::Exact,
        _ => Verify::Tolerance(3e-4),
    };
    let opts = RunOptions { pace: p.flag("pace"), verify, ..Default::default() };
    let sockets: usize = p.parse_or("sockets", 0usize)?;
    let framing = Framing::parse(p.get_or("framing", "mixed"))?;
    let served: Vec<String> = cfg.specs.iter().map(|s| s.to_string()).collect();
    println!("serving {} spec(s): {}", served.len(), served.join(", "));
    let mut log = BenchLog::new();
    for name in names {
        // The lstm scenario serves whole cell steps through the graph
        // layer rather than a flat activation trace — its own driver
        // (a cell graph per request mix makes no sense as a Trace).
        if name == "lstm" {
            let row = run_lstm_scenario(p, &backend, backend_name, batch, &cfg, seed, scale)?;
            log.push_row(row);
            continue;
        }
        // Streaming scenarios pulse long sequences through server-side
        // sessions instead of replaying one-shot requests — their own
        // driver (in-process, or over real sockets with --sockets).
        if name.starts_with("stream-") {
            let plan = build_stream_plan(name, seed, batch, scale, &cfg.specs)?;
            let coord =
                Coordinator::start(backend.clone(), cfg.clone()).map_err(|e| e.to_string())?;
            let shards_per_method = coord.shards_per_method();
            let (out, coord) = if sockets > 0 {
                let coord = Arc::new(coord);
                let server = NetServer::start(coord.clone(), "127.0.0.1:0")
                    .map_err(|e| format!("starting net front-end: {e}"))?;
                let result = run_stream_sockets(&coord, &server, &plan, sockets, framing);
                server.stop();
                let coord = Arc::try_unwrap(coord)
                    .map_err(|_| "net front-end still holds the coordinator".to_string())?;
                (result?, coord)
            } else {
                (run_stream(&coord, &plan)?, coord)
            };
            let s = out.stream.as_ref().expect("stream driver fills session stats");
            let secs = out.wall.as_secs_f64().max(1e-9);
            println!(
                "scenario {name:13} seed {seed}: {} sessions, {} pulses ({} elements) in \
                 {:.3}s on '{backend_name}' × {} shards/method",
                s.sessions, s.pulses, out.elements, secs, shards_per_method,
            );
            if let Some(net) = &out.net {
                println!(
                    "  sockets: {} connections ({} framing), {} B in / {} B out",
                    net.connections, net.framing, net.bytes_in, net.bytes_out,
                );
            }
            println!(
                "  pulse round-trip µs: p50 {:.0}  p95 {:.0}  p99 {:.0};  {:.0} pulses/s, \
                 {:.2} Mact/s;  {} backpressure retries, {} evicted",
                s.pulse_latency.p50(),
                s.pulse_latency.p95(),
                s.pulse_latency.p99(),
                s.pulses as f64 / secs,
                out.elements as f64 / secs / 1e6,
                out.retries,
                s.evicted,
            );
            if s.stream_cycles_per_element > 0.0 {
                println!(
                    "  warm-stream steady state: {:.3} simulated cycles/element \
                     (per-batch re-fill would pay the pipeline depth every pulse)",
                    s.stream_cycles_per_element,
                );
            }
            println!(
                "  verified {}/{} pulse replies bit-exact against the cold golden replay",
                out.verified, out.completed
            );
            log.push_row(out.to_json(backend_name, shards_per_method, batch));
            coord.shutdown();
            continue;
        }
        let trace = scenario::build_trace(name, seed, batch, scale, &cfg.specs)?;
        let coord =
            Coordinator::start(backend.clone(), cfg.clone()).map_err(|e| e.to_string())?;
        let shards_per_method = coord.shards_per_method();
        // Socket mode replays the trace through the real TCP
        // front-end (pipelined over N connections, both framings);
        // otherwise the classic in-process driver submits directly.
        let (out, coord) = if sockets > 0 {
            let coord = Arc::new(coord);
            let server = NetServer::start(coord.clone(), "127.0.0.1:0")
                .map_err(|e| format!("starting net front-end: {e}"))?;
            let sopts = SocketRunOptions {
                connections: sockets,
                framing,
                verify,
                pace: opts.pace,
                ..Default::default()
            };
            let result = run_trace_sockets(&coord, &server, &trace, &sopts);
            server.stop();
            let coord = Arc::try_unwrap(coord)
                .map_err(|_| "net front-end still holds the coordinator".to_string())?;
            (result?, coord)
        } else {
            (scenario::run_trace(&coord, &trace, &opts)?, coord)
        };
        let m = &out.metrics;
        let secs = out.wall.as_secs_f64().max(1e-9);
        println!(
            "scenario {name:8} seed {seed}: {} reqs ({} elements) in {:.3}s on \
             '{backend_name}' × {} shards/method [{:?}]",
            out.completed,
            out.elements,
            secs,
            shards_per_method,
            cfg.route,
        );
        if let Some(net) = &out.net {
            println!(
                "  sockets: {} connections ({} framing), {} B in / {} B out;  \
                 round-trip µs: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {}",
                net.connections,
                net.framing,
                net.bytes_in,
                net.bytes_out,
                net.conn_latency.p50(),
                net.conn_latency.p95(),
                net.conn_latency.p99(),
                net.conn_latency.max,
            );
        }
        println!(
            "  throughput {:.0} req/s, {:.2} Mact/s;  {} batches ({} packed), \
             fill {:.1}%, {} backpressure retries",
            out.completed as f64 / secs,
            out.elements as f64 / secs / 1e6,
            m.batches,
            m.packed_batches,
            100.0 * m.fill_rate(),
            out.retries,
        );
        println!(
            "  latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {}  (mean {:.0})",
            m.p50_us(),
            m.p95_us(),
            m.p99_us(),
            m.latency_us_max(),
            m.mean_latency_us(),
        );
        if m.sim_cycles > 0 {
            println!(
                "  simulated hw latency: {} cycles total ({:.1} cycles/batch, \
                 {:.2} cycles/element, steady-state {:.3} cycles/fed element)",
                m.sim_cycles,
                m.sim_cycles as f64 / m.batches.max(1) as f64,
                m.sim_cycles as f64 / m.elements.max(1) as f64,
                m.sim_cycles_per_element(),
            );
        }
        match verify {
            Verify::Exact => println!(
                "  verified {}/{} replies bit-exact against the compiled golden kernels",
                out.verified, out.completed
            ),
            Verify::Tolerance(tol) => println!(
                "  verified {}/{} replies within {tol:.1e} of the golden kernels",
                out.verified, out.completed
            ),
            Verify::Off => {}
        }
        log.push_row(out.to_json(backend_name, shards_per_method, batch));
        coord.shutdown();
    }
    let stats = tanh_vlsi::approx::Registry::global().stats();
    println!(
        "\nkernel cache: {} compiles, {} hits across the run \
         (shards × scenarios share one kernel per spec)",
        stats.compiles, stats.hits
    );
    let out_path = p.get_or("out", DEFAULT_SERVE_LOG);
    log.write(out_path).map_err(|e| e.to_string())?;
    let text = std::fs::read_to_string(out_path).map_err(|e| e.to_string())?;
    let rows = scenario::validate_serve_log(&text)?;
    println!("\nwrote {rows} scenario row(s) to {out_path} (schema OK)");
    Ok(())
}

/// The `lstm` scenario: whole LSTM cell steps served through the
/// coordinator via the graph layer. The cell graph is rewritten
/// (sigmoid-into-tanh fusion, requant merge, dedup, prune) so all gate
/// nonlinearities ride shared Registry tanh kernels; every step is
/// verified bit-exactly against a direct golden execution and against
/// the f64 reference under the cell's per-gate error budget.
#[allow(clippy::too_many_arguments)]
fn run_lstm_scenario(
    p: &tanh_vlsi::util::cli::Parsed,
    backend: &Arc<dyn EvalBackend>,
    backend_name: &str,
    batch: usize,
    cfg: &CoordinatorConfig,
    seed: u64,
    scale: f64,
) -> Result<tanh_vlsi::util::json::Json, String> {
    // --spec selects the gate design point (first spec if several were
    // given); the default is the Table I PWL operating point.
    let cell_cfg = match p.get("spec") {
        Some(_) => CellConfig::with_spec(cfg.specs[0]),
        None => CellConfig::table1_lstm(),
    };
    let graph = lstm_cell(&cell_cfg)?;
    let (fused, rw) = optimize(&graph)?;
    println!(
        "scenario lstm     seed {seed}: gate spec {} (budget {:.1e}); rewrites: \
         {} sigmoids fused, {} requants merged, {} deduped, {} pruned",
        cell_cfg.spec, cell_cfg.budget, rw.fused_sigmoids, rw.merged_requants,
        rw.deduped_nodes, rw.pruned_nodes,
    );
    let mut coord_cfg = cfg.clone();
    coord_cfg.specs = fused.activation_specs();
    let coord =
        Coordinator::start(backend.clone(), coord_cfg).map_err(|e| e.to_string())?;
    let shards_per_method = coord.shards_per_method();
    let mut run = CellRunConfig::scaled(seed, scale);
    run.lanes = run.lanes.min(batch.max(1));
    let start = std::time::Instant::now();
    let stats = run_lstm_cells(&coord, &cell_cfg, &fused, &run)?;
    let wall = start.elapsed();
    let out = scenario::ScenarioOutcome {
        name: "lstm".into(),
        seed,
        specs: fused.activation_specs().iter().map(|s| s.to_string()).collect(),
        submitted: stats.requests,
        completed: stats.requests,
        failed: 0,
        retries: stats.retries,
        elements: stats.elements,
        verified: stats.requests,
        wall,
        metrics: coord.metrics(),
        net: None,
        cells: Some(scenario::CellStats {
            cell_steps: stats.cell_steps,
            gate_max_err: stats.gate_max_err,
        }),
        stream: None,
    };
    let secs = wall.as_secs_f64().max(1e-9);
    println!(
        "  {} cell steps ({} sequences × {} steps × {} lanes) in {:.3}s on \
         '{backend_name}' × {} shards/method: {:.0} steps/s, {:.2} Mact/s",
        stats.cell_steps,
        run.sequences,
        run.steps,
        run.lanes,
        secs,
        shards_per_method,
        stats.cell_steps as f64 / secs,
        stats.elements as f64 / secs / 1e6,
    );
    println!(
        "  {} activation requests served ({} elements, {} backpressure retries); \
         every step bit-exact vs direct golden execution",
        stats.requests, stats.elements, stats.retries,
    );
    println!(
        "  per-gate max |served − f64 reference| = {:.3e} (budget {:.1e})",
        stats.gate_max_err, cell_cfg.budget,
    );
    let row = out.to_json(backend_name, shards_per_method, batch);
    coord.shutdown();
    Ok(row)
}

/// Legacy mode: `--requests N` windowed synthetic load.
fn cmd_serve_legacy(
    p: &tanh_vlsi::util::cli::Parsed,
    backend: Arc<dyn EvalBackend>,
    backend_name: &str,
    cfg: CoordinatorConfig,
) -> Result<(), String> {
    let n: usize = p.parse_or("requests", 1000usize)?;
    let req_size: usize = p.parse_or("request-size", 64usize)?;
    let specs = cfg.specs.clone();
    let coord = Coordinator::start(backend, cfg).map_err(|e| e.to_string())?;
    let mut g = Prng::new(42);
    let start = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        let spec = &specs[i % specs.len()];
        let values: Vec<f32> = (0..req_size).map(|_| g.f64_in(-6.0, 6.0) as f32).collect();
        pending.push(coord.submit_spec(spec, values).map_err(|e| e.to_string())?);
        // Drain in windows to bound memory.
        if pending.len() >= 256 {
            for rx in pending.drain(..) {
                rx.recv().map_err(|_| "reply dropped")?.outcome.map_err(|e| e.to_string())?;
            }
        }
    }
    for rx in pending {
        rx.recv().map_err(|_| "reply dropped")?.outcome.map_err(|e| e.to_string())?;
    }
    let elapsed = start.elapsed();
    let m = coord.metrics();
    println!(
        "\nserved {} requests ({} activations) in {:.3}s on '{backend_name}' × {} shards/method",
        m.requests,
        m.elements,
        elapsed.as_secs_f64(),
        coord.shards_per_method(),
    );
    println!(
        "throughput: {:.0} req/s, {:.2} Mact/s",
        m.requests as f64 / elapsed.as_secs_f64(),
        m.elements as f64 / elapsed.as_secs_f64() / 1e6
    );
    println!(
        "batches: {} (fill {:.1}%, efficiency {:.1}%)",
        m.batches,
        100.0 * m.fill_rate(),
        100.0 * m.batch_efficiency(),
    );
    println!(
        "latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {}  (mean {:.0})",
        m.p50_us(),
        m.p95_us(),
        m.p99_us(),
        m.latency_us_max(),
        m.mean_latency_us(),
    );
    coord.shutdown();
    Ok(())
}

/// `netcheck`: fires the wire-protocol regression payloads (the bugs
/// fixed in the nonblocking front-end rework, plus the wire-layer
/// truncation bugs: the unchecked u32 reply length prefix and the
/// `as u16` spec-id table) at a live loopback server and prints each
/// reply — tier1.sh greps the output for the
/// expected `bad_request` rejections. Exits nonzero if the server
/// misbehaves at the transport level; the reply *content* judgment is
/// left to the caller's greps so a regression shows the actual reply.
fn cmd_netcheck(p: &tanh_vlsi::util::cli::Parsed) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use tanh_vlsi::backend::ErrorCode;
    use tanh_vlsi::bench::sockets::spec_id_table;
    use tanh_vlsi::coordinator::{
        try_bin_reply_frame, NetConfig, BIN_REPLY_MAGIC, BIN_REQUEST_MAGIC,
    };

    let batch: usize = p.parse_or("batch", 256usize)?;
    let backend = backend::by_name("golden", batch)?;
    let coord = Arc::new(
        Coordinator::start(backend, CoordinatorConfig::with_batch(batch))
            .map_err(|e| e.to_string())?,
    );
    // A small frame cap so the oversized-line probe stays cheap.
    let ncfg = NetConfig { max_frame_bytes: 4096, ..NetConfig::default() };
    let server = NetServer::start_with(coord.clone(), "127.0.0.1:0", ncfg)
        .map_err(|e| e.to_string())?;
    let addr = server.addr();

    let line_reply = |bytes: &[u8]| -> Result<String, String> {
        let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        s.write_all(bytes).map_err(|e| e.to_string())?;
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).map_err(|e| e.to_string())?;
        if line.is_empty() {
            return Err("server closed the connection without a reply".into());
        }
        Ok(line.trim_end().to_string())
    };

    // Bugfix 1: non-numeric / non-finite `values` entries must be
    // rejected by index, never silently dropped.
    println!(
        "non-numeric-entry    {}",
        line_reply(b"{\"method\":\"pwl\",\"values\":[1.0,\"x\",2.0]}\n")?
    );
    // Bugfix 2 companion: a bare NaN token is invalid JSON and must be
    // refused at the parser, not smuggled in as a float.
    println!(
        "nan-entry            {}",
        line_reply(b"{\"method\":\"pwl\",\"values\":[NaN]}\n")?
    );
    // Bugfix 3: a line over the frame cap answers bad_request instead
    // of buffering without bound.
    let mut big = vec![b'x'; 64 * 1024];
    big.push(b'\n');
    println!("oversized-line       {}", line_reply(&big)?);
    // …and the binary path enforces the same cap from the frame header.
    let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    let mut huge = vec![BIN_REQUEST_MAGIC];
    huge.extend_from_slice(&(1u32 << 24).to_le_bytes());
    s.write_all(&huge).map_err(|e| e.to_string())?;
    let mut header = [0u8; 5];
    s.read_exact(&mut header).map_err(|e| e.to_string())?;
    if header[0] != BIN_REPLY_MAGIC {
        return Err(format!("bad binary reply magic 0x{:02x}", header[0]));
    }
    let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]) as usize;
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).map_err(|e| e.to_string())?;
    let code = ErrorCode::from_u8(body[0]).map(|c| c.as_str()).unwrap_or("ok");
    println!(
        "oversized-bin-frame  {{\"code\":\"{code}\",\"error\":\"{}\"}}",
        String::from_utf8_lossy(&body[1..])
    );
    // Wire-truncation bugfix 1: a reply body past the length-prefix cap
    // must be refused by the frame builder, never encoded with a
    // wrapped u32 prefix. Probed at the library layer with an
    // injectable cap (a >4 GiB body is unallocatable here); the
    // server's encoder routes through this same checked builder.
    let cap_err = match try_bin_reply_frame(0, &[0u8; 8192], 4096) {
        Err(e) => e,
        Ok(_) => return Err("reply-frame-cap probe: oversized body encoded anyway".into()),
    };
    println!("reply-frame-cap      {{\"code\":\"bad_request\",\"error\":\"{cap_err}\"}}");
    // Wire-truncation bugfix 2: a served-spec list past the u16 binary
    // address space must fail table construction, never wrap `as u16`
    // and alias two specs onto one id.
    let too_many = vec![MethodSpec::table1(MethodId::Pwl); (u16::MAX as usize) + 2];
    let table_err = match spec_id_table(&too_many) {
        Err(e) => e,
        Ok(_) => return Err("spec-id-overflow probe: 65537 specs got u16 ids".into()),
    };
    println!("spec-id-overflow     {{\"code\":\"bad_request\",\"error\":\"{table_err}\"}}");

    server.stop();
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
    Ok(())
}
