//! §IV design-complexity report: component inventories per method,
//! priced into area/delay/latency by the cost model, plus the pipelined
//! datapath depths from the hw simulator.

use crate::approx::{table1_suite, IoSpec};
use crate::cost::{CostModel, UnitLibrary};
use crate::fixed::QFormat;
use crate::hw::table1_pipeline;
use crate::util::table::TextTable;

/// Renders the full complexity comparison.
pub fn render() -> String {
    let io = IoSpec::table1();
    let model = CostModel::new();
    let lib = UnitLibrary::default();
    let mut t = TextTable::new(&[
        "id", "method", "add", "mul", "sq", "div", "LUT entries", "LUT bits", "mux2/4",
        "area (GE)", "stage delay (FO4)", "pipeline (cyc)",
    ]);
    for m in table1_suite() {
        let inv = m.inventory(io);
        let cost = model.price(&inv);
        let pipe = table1_pipeline(m.id(), QFormat::S_15);
        t.row(vec![
            m.id().label().to_string(),
            m.describe(),
            inv.adders.to_string(),
            inv.multipliers.to_string(),
            inv.squarers.to_string(),
            inv.dividers.to_string(),
            inv.lut_entries.to_string(),
            inv.lut_bits.to_string(),
            format!("{}/{}", inv.mux2, inv.mux4),
            format!("{:.0}", cost.area_ge),
            format!("{:.1}", pipe.critical_delay(&lib)),
            pipe.latency().to_string(),
        ]);
    }
    format!(
        "DESIGN COMPLEXITY (paper §IV) — component inventory, priced by the\n\
         unit gate library; pipeline depth from the cycle-level datapath\n\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_six_methods() {
        let text = super::render();
        for label in ["PWL", "Taylor", "CatmullRom", "Velocity", "Lambert"] {
            assert!(text.contains(label), "{label}");
        }
        assert!(text.contains("area (GE)"));
    }
}
