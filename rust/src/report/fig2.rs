//! Fig 2 — max abs error and MSE vs tunable parameter, one panel per
//! method. Rendered as text tables + CSV series for plotting.

use std::path::Path;

use crate::approx::MethodId;
use crate::error::{sweep_fig2, Fig2Series, InputGrid};
use crate::fixed::QFormat;
use crate::util::csv::Csv;
use crate::util::table::{sci, step_str, TextTable};

/// Sweeps all six panels on the Table I grid.
pub fn compute() -> Vec<Fig2Series> {
    let grid = InputGrid::table1();
    MethodId::all()
        .into_iter()
        .map(|id| sweep_fig2(id, grid, QFormat::S_15))
        .collect()
}

/// Renders one panel as a text table.
pub fn render_panel(s: &Fig2Series) -> String {
    let mut t = TextTable::new(&[s.param_name, "max error", "MSE", "RMS"]);
    for p in &s.points {
        let param = if s.id == MethodId::Lambert {
            format!("{}", p.param as u64)
        } else {
            step_str(p.param)
        };
        t.row(vec![param, sci(p.metrics.max_abs), sci(p.metrics.mse), sci(p.metrics.rms)]);
    }
    format!("Fig 2 panel — {} ({})\n{}", s.id.name(), s.id.label(), t.render())
}

/// Renders all panels.
pub fn render(series: &[Fig2Series]) -> String {
    let mut out = String::from(
        "FIG 2 — maximum absolute and mean square error as a function of\n\
         configuration parameter for various approximations\n\n",
    );
    for s in series {
        out.push_str(&render_panel(s));
        out.push('\n');
    }
    out
}

/// Writes one CSV per panel into `dir` (for external plotting).
pub fn write_csv(series: &[Fig2Series], dir: &Path) -> std::io::Result<()> {
    for s in series {
        let mut csv = Csv::new(&["param", "max_error", "mse", "rms"]);
        for p in &s.points {
            csv.row(vec![
                format!("{}", p.param),
                format!("{:e}", p.metrics.max_abs),
                format!("{:e}", p.metrics.mse),
                format!("{:e}", p.metrics.rms),
            ]);
        }
        csv.write_file(&dir.join(format!("fig2_{}.csv", s.id.name().replace(' ', "_"))))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panels_render_and_write() {
        // Small grid for test speed: same code path, coarser input.
        let grid = InputGrid::ranged(QFormat::new(3, 8), 6.0);
        let series: Vec<Fig2Series> = MethodId::all()
            .into_iter()
            .map(|id| sweep_fig2(id, grid, QFormat::S_15))
            .collect();
        let text = render(&series);
        assert!(text.contains("FIG 2"));
        assert!(text.contains("PWL"));
        assert!(text.contains("Lambert"));
        let dir = std::env::temp_dir().join("tanh_vlsi_fig2_test");
        write_csv(&series, &dir).unwrap();
        assert!(dir.join("fig2_PWL.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
