//! One-shot consolidated report: every paper artefact regenerated into
//! a single markdown document (`tanh-vlsi report --out REPORT.md`).

use std::fmt::Write as _;

use crate::approx::velocity::Velocity;
use crate::approx::{table1_suite, IoSpec, MethodSpec};
use crate::cost::CostModel;
use crate::error::{histogram, measure_spec, InputGrid};
use crate::explore::{explore, pareto_frontier, ExploreConfig};
use crate::fixed::QFormat;

use super::{complexity, fig2, table1, table2};

/// Options for the consolidated report.
#[derive(Clone, Debug)]
pub struct ReportOptions {
    /// Include the Fig 2 sweeps (the slowest section).
    pub fig2: bool,
    /// Include the design-space exploration.
    pub explore: bool,
    /// Grid stride for the exploration (1 = exhaustive).
    pub explore_stride: usize,
    /// Extra named design points (`--spec`): each gets an exhaustive
    /// error row in its own section.
    pub specs: Vec<MethodSpec>,
}

impl Default for ReportOptions {
    fn default() -> Self {
        ReportOptions { fig2: true, explore: true, explore_stride: 8, specs: Vec::new() }
    }
}

/// Generates the full markdown report.
pub fn generate(opts: ReportOptions) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# tanh-vlsi — regenerated evaluation\n\n\
         Reproduction of Chandra (2020), every table and figure computed\n\
         by this build. See EXPERIMENTS.md for the paper-vs-measured\n\
         discussion.\n"
    );

    let _ = writeln!(out, "## Table I\n\n```\n{}```\n", table1::render(&table1::compute()));

    // The measured-cost companion: the same six configurations with
    // the analytic §IV cost model next to measurements off the lowered
    // hw pipelines. "cycles (hw)"/"FO4 (hw)"/"area GE (hw)" are read
    // from the audited Fig 3/4/5 datapaths; "sim cyc/elt" is the
    // steady-state cycles/element of a warm streaming batch — the
    // §IV.H one-result-per-cycle claim, measured rather than assumed.
    let _ = writeln!(
        out,
        "## Table I companion — measured vs analytic hw cost\n\n```\n{}```\n",
        table1::render_measured(&table1::compute_measured())
    );

    if opts.fig2 {
        let series = fig2::compute();
        let _ = writeln!(out, "## Fig 2\n\n```\n{}```\n", fig2::render(&series));
    }

    let _ = writeln!(out, "## Table II\n\n```\n{}```\n", table2::render(&Velocity::table1()));

    let _ = writeln!(out, "## §IV complexity\n\n```\n{}```\n", complexity::render());

    // Error histograms (one per method) — the distribution view.
    let _ = writeln!(out, "## Error distribution (output ulps, Table I grid)\n");
    let grid = InputGrid::table1();
    for m in table1_suite() {
        let h = histogram(m.as_ref(), grid, QFormat::S_15);
        let _ = writeln!(
            out,
            "### {}\n\n```\n{}```\n(≤1 ulp: {:.2}%)\n",
            m.describe(),
            h.render(),
            100.0 * h.fraction_within(1.0)
        );
    }

    if opts.explore {
        let points = explore(ExploreConfig { stride: opts.explore_stride, ..Default::default() });
        let frontier = pareto_frontier(&points);
        let _ = writeln!(
            out,
            "## Design-space Pareto frontier ({} of {} points)\n",
            frontier.len(),
            points.len()
        );
        // `cost` labels each row's provenance: `analytic` rows price
        // the §IV inventory, `measured` rows (an `--backend hw`
        // exploration) read the lowered pipeline.
        let _ =
            writeln!(out, "| method | param | spec | max err | area GE | latency | cyc/elt | cost |");
        let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
        for p in &frontier {
            let _ = writeln!(
                out,
                "| {} | {} | `{}` | {:.2e} | {:.0} | {} | {:.2} | {} |",
                p.id.name(),
                p.param,
                p.spec,
                p.max_err,
                p.area_ge,
                p.latency_cycles,
                p.cycles_per_element,
                p.cost_source,
            );
        }
    }

    if !opts.specs.is_empty() {
        let _ = writeln!(out, "\n## Named design points (--spec)\n");
        let _ = writeln!(out, "| spec | max err | RMS | max ulp | points |");
        let _ = writeln!(out, "|---|---|---|---|---|");
        for spec in &opts.specs {
            let e = measure_spec(spec);
            let _ = writeln!(
                out,
                "| `{spec}` | {:.2e} | {:.2e} | {:.2} | {} |",
                e.max_abs, e.rms, e.max_ulp, e.points
            );
        }
    }

    // Cost summary as markdown for quick diffing.
    let _ = writeln!(out, "\n## Priced inventories (Table I configs)\n");
    let model = CostModel::new();
    let io = IoSpec::table1();
    let _ = writeln!(out, "| method | area GE | LUT GE | stage FO4 |");
    let _ = writeln!(out, "|---|---|---|---|");
    for m in table1_suite() {
        let c = model.price(&m.inventory(io));
        let _ = writeln!(
            out,
            "| {} | {:.0} | {:.0} | {:.1} |",
            m.describe(),
            c.area_ge,
            c.lut_area_ge,
            c.stage_delay_fo4
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_contains_all_sections() {
        // Skip the slow sections; structure check only.
        let r = generate(ReportOptions { fig2: false, explore: false, ..Default::default() });
        assert!(r.contains("# tanh-vlsi"));
        assert!(r.contains("## Table I"));
        assert!(r.contains("measured vs analytic hw cost"));
        assert!(r.contains("sim cyc/elt"));
        assert!(r.contains("## Table II"));
        assert!(r.contains("## §IV complexity"));
        assert!(r.contains("## Error distribution"));
        assert!(r.contains("Lambert(K=7)"));
        // No named-design-point section unless specs were requested.
        assert!(!r.contains("Named design points"));
    }

    #[test]
    fn spec_section_lists_requested_points() {
        let spec = MethodSpec::parse("pwl:step=1/16:in=s2.5:out=s.7:dom=4").unwrap();
        let r = generate(ReportOptions {
            fig2: false,
            explore: false,
            specs: vec![spec],
            ..Default::default()
        });
        assert!(r.contains("Named design points"));
        assert!(r.contains("pwl:step=1/16:in=S2.5:out=S.7:dom=4"));
    }
}
