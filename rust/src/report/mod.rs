//! Report renderers: regenerate every table and figure of the paper as
//! text/markdown/CSV (the evaluation surface of the reproduction).
//!
//! | renderer | paper artefact |
//! |---|---|
//! | [`fig2`]        | Fig 2 — error vs tunable parameter, 6 panels |
//! | [`table1`]      | Table I — selected configurations + errors |
//! | [`table2`]      | Table II — multi-bit velocity-factor lookup |
//! | [`table3`]      | Table III — 1-ulp parameter vs I/O format |
//! | [`complexity`]  | §IV component counts, priced by the cost model |

pub mod complexity;
pub mod fig2;
pub mod full;
pub mod table1;
pub mod table2;
pub mod table3;
