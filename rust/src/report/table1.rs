//! Table I — "Configurations selected for analysis (max input 6.0,
//! 12-bit input precision, 15-bit output precision)" — plus the
//! measured-cost companion: the same six configurations with the
//! analytic §IV cost model side by side with measurements off the
//! lowered hw pipelines (simulated cycles, critical path, area).

use crate::approx::MethodSpec;
use crate::backend::{analytic_cost, CostProbe, HwBackend};
use crate::error::measure_spec;
use crate::util::table::{sci, TextTable};

/// One computed Table I row alongside the paper's reported values.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Paper label (A, B1, …).
    pub label: &'static str,
    /// Method + configuration description.
    pub config: String,
    /// Our measured RMS error (the paper's "MSE" column tracks RMS —
    /// see `error` module docs).
    pub rms: f64,
    /// Our measured max abs error.
    pub max_err: f64,
    /// Paper-reported "MSE" value.
    pub paper_mse: f64,
    /// Paper-reported max error.
    pub paper_max: f64,
}

/// The paper's reported numbers, in row order.
pub const PAPER_VALUES: [(f64, f64); 6] = [
    (1.24e-5, 4.65e-5), // A   PWL 1/64
    (1.16e-5, 3.65e-5), // B1  Taylor quadratic 1/16
    (1.17e-5, 3.23e-5), // B2  Taylor cubic 1/8
    (1.13e-5, 3.63e-5), // C   Catmull-Rom 1/16
    (9.53e-6, 3.85e-5), // D   Velocity 1/128
    (1.50e-5, 4.87e-5), // E   Lambert K=7
];

/// Computes all six rows by exhaustive sweep of the Table I grid.
/// Rows are the six Table I specs measured through the shared kernel
/// cache ([`measure_spec`]) — numerically identical to the old
/// per-call compile, but a `report` run that also regenerates Fig 2 or
/// the exploration no longer compiles these kernels twice.
pub fn compute() -> Vec<Table1Row> {
    MethodSpec::table1_all()
        .into_iter()
        .zip(PAPER_VALUES)
        .map(|(spec, (paper_mse, paper_max))| {
            let e = measure_spec(&spec);
            Table1Row {
                label: spec.method_id().label(),
                config: spec.build().describe(),
                rms: e.rms,
                max_err: e.max_abs,
                paper_mse,
                paper_max,
            }
        })
        .collect()
}

/// Renders the comparison table.
pub fn render(rows: &[Table1Row]) -> String {
    let mut t = TextTable::new(&[
        "id", "configuration", "RMS (ours)", "paper MSE", "max err (ours)", "paper max",
    ]);
    for r in rows {
        t.row(vec![
            r.label.to_string(),
            r.config.clone(),
            sci(r.rms),
            sci(r.paper_mse),
            sci(r.max_err),
            sci(r.paper_max),
        ]);
    }
    format!(
        "TABLE I — configurations selected for analysis\n\
         (max input 6.0, 12-bit input precision, 15-bit output precision)\n\n{}",
        t.render()
    )
}

/// One measured-vs-analytic cost row: the same Table I configuration
/// priced by the §IV inventory model and measured off its lowered
/// Fig 3/4/5 pipeline.
#[derive(Clone, Debug)]
pub struct MeasuredCostRow {
    /// Paper label (A, B1, …).
    pub label: &'static str,
    /// The design-point spec string.
    pub spec: String,
    /// Analytic latency (inventory pipeline stages).
    pub analytic_cycles: u32,
    /// Measured latency (lowered pipeline depth).
    pub measured_cycles: u32,
    /// Analytic critical stage delay (FO4).
    pub analytic_fo4: f64,
    /// Measured critical stage delay (slowest lowered stage, FO4).
    pub measured_fo4: f64,
    /// Analytic area (priced inventory, GE).
    pub analytic_area: f64,
    /// Measured area (unit library over instantiated blocks, GE).
    pub measured_area: f64,
    /// Netlist latency (registered stage count of the elaborated RTL).
    pub netlist_cycles: u32,
    /// Netlist critical path (longest comb path between ranks, FO4).
    pub netlist_fo4: f64,
    /// Netlist area (cell-by-cell sum over the elaborated RTL, GE).
    pub netlist_area: f64,
    /// Measured steady-state cycles per element (streaming probe).
    pub sim_cycles_per_element: f64,
}

/// Computes the measured-cost companion rows: every Table I spec
/// probed through the hw backend (lowered + audited) next to its
/// analytic §IV cost.
pub fn compute_measured() -> Vec<MeasuredCostRow> {
    let hw = HwBackend::new();
    let netlist = crate::rtl::NetlistProbe::new();
    MethodSpec::table1_all()
        .into_iter()
        .map(|spec| {
            let analytic = analytic_cost(&spec).expect("Table I specs are valid");
            let measured =
                hw.probe_cost(&spec).expect("Table I specs always lower to hw datapaths");
            let rtl = netlist
                .probe_cost(&spec)
                .expect("Table I specs always elaborate to audited netlists");
            MeasuredCostRow {
                label: spec.method_id().label(),
                spec: spec.to_string(),
                analytic_cycles: analytic.latency_cycles,
                measured_cycles: measured.latency_cycles,
                analytic_fo4: analytic.stage_delay_fo4,
                measured_fo4: measured.stage_delay_fo4,
                analytic_area: analytic.area_ge,
                measured_area: measured.area_ge,
                netlist_cycles: rtl.latency_cycles,
                netlist_fo4: rtl.stage_delay_fo4,
                netlist_area: rtl.area_ge,
                sim_cycles_per_element: measured.cycles_per_element,
            }
        })
        .collect()
}

/// Renders the measured-vs-analytic companion table.
pub fn render_measured(rows: &[MeasuredCostRow]) -> String {
    let mut t = TextTable::new(&[
        "id",
        "cycles (model)",
        "cycles (hw)",
        "cycles (rtl)",
        "FO4 (model)",
        "FO4 (hw)",
        "FO4 (rtl)",
        "area GE (model)",
        "area GE (hw)",
        "area GE (rtl)",
        "sim cyc/elt",
    ]);
    for r in rows {
        t.row(vec![
            r.label.to_string(),
            r.analytic_cycles.to_string(),
            r.measured_cycles.to_string(),
            r.netlist_cycles.to_string(),
            format!("{:.1}", r.analytic_fo4),
            format!("{:.1}", r.measured_fo4),
            format!("{:.1}", r.netlist_fo4),
            format!("{:.0}", r.analytic_area),
            format!("{:.0}", r.measured_area),
            format!("{:.0}", r.netlist_area),
            format!("{:.2}", r.sim_cycles_per_element),
        ]);
    }
    format!(
        "TABLE I (companion) — measured hw cost vs analytic §IV model vs RTL netlist\n\
         (\"model\" prices the component inventory; \"hw\" measures the lowered\n\
         Fig 3/4/5 pipeline: depth, slowest stage, instantiated units, and the\n\
         steady-state cycles/element of a warm streaming batch; \"rtl\" prices the\n\
         elaborated netlist cell by cell, critical path over the cell graph)\n\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_within_factor_two_of_paper() {
        // The reproduction criterion: same error band, same ordering.
        for r in compute() {
            assert!(
                r.max_err < 2.0 * r.paper_max && r.max_err > 0.3 * r.paper_max,
                "{}: ours {} vs paper {}",
                r.label,
                r.max_err,
                r.paper_max
            );
            assert!(
                r.rms < 2.0 * r.paper_mse && r.rms > 0.3 * r.paper_mse,
                "{}: rms {} vs paper {}",
                r.label,
                r.rms,
                r.paper_mse
            );
        }
    }

    #[test]
    fn render_contains_all_labels() {
        let text = render(&compute());
        for label in ["A ", "B1", "B2", "C ", "D ", "E "] {
            assert!(text.contains(label.trim()), "{label}");
        }
        assert!(text.contains("TABLE I"));
    }

    #[test]
    fn measured_companion_covers_all_rows_and_is_self_consistent() {
        let rows = compute_measured();
        assert_eq!(rows.len(), 6);
        let text = render_measured(&rows);
        assert!(text.contains("measured hw cost"));
        assert!(text.contains("sim cyc/elt"));
        for r in &rows {
            assert!(text.contains(r.label), "{} missing", r.label);
            // Both sources produce positive, same-order-of-magnitude
            // numbers (the regression band lives in tests/backends.rs).
            assert!(r.analytic_cycles >= 1 && r.measured_cycles >= 1, "{}", r.spec);
            assert!(r.analytic_fo4 > 0.0 && r.measured_fo4 > 0.0, "{}", r.spec);
            assert!(r.analytic_area > 0.0 && r.measured_area > 0.0, "{}", r.spec);
            // The netlist tier registers exactly the pipeline's ranks
            // and prices a real structure.
            assert_eq!(r.netlist_cycles, r.measured_cycles, "{}", r.spec);
            assert!(r.netlist_fo4 > 0.0 && r.netlist_area > 0.0, "{}", r.spec);
            // Warm pipelined streaming retires one result per cycle.
            assert_eq!(r.sim_cycles_per_element, 1.0, "{}", r.spec);
        }
    }
}
