//! Table I — "Configurations selected for analysis (max input 6.0,
//! 12-bit input precision, 15-bit output precision)".

use crate::approx::MethodSpec;
use crate::error::measure_spec;
use crate::util::table::{sci, TextTable};

/// One computed Table I row alongside the paper's reported values.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Paper label (A, B1, …).
    pub label: &'static str,
    /// Method + configuration description.
    pub config: String,
    /// Our measured RMS error (the paper's "MSE" column tracks RMS —
    /// see `error` module docs).
    pub rms: f64,
    /// Our measured max abs error.
    pub max_err: f64,
    /// Paper-reported "MSE" value.
    pub paper_mse: f64,
    /// Paper-reported max error.
    pub paper_max: f64,
}

/// The paper's reported numbers, in row order.
pub const PAPER_VALUES: [(f64, f64); 6] = [
    (1.24e-5, 4.65e-5), // A   PWL 1/64
    (1.16e-5, 3.65e-5), // B1  Taylor quadratic 1/16
    (1.17e-5, 3.23e-5), // B2  Taylor cubic 1/8
    (1.13e-5, 3.63e-5), // C   Catmull-Rom 1/16
    (9.53e-6, 3.85e-5), // D   Velocity 1/128
    (1.50e-5, 4.87e-5), // E   Lambert K=7
];

/// Computes all six rows by exhaustive sweep of the Table I grid.
/// Rows are the six Table I specs measured through the shared kernel
/// cache ([`measure_spec`]) — numerically identical to the old
/// per-call compile, but a `report` run that also regenerates Fig 2 or
/// the exploration no longer compiles these kernels twice.
pub fn compute() -> Vec<Table1Row> {
    MethodSpec::table1_all()
        .into_iter()
        .zip(PAPER_VALUES)
        .map(|(spec, (paper_mse, paper_max))| {
            let e = measure_spec(&spec);
            Table1Row {
                label: spec.method_id().label(),
                config: spec.build().describe(),
                rms: e.rms,
                max_err: e.max_abs,
                paper_mse,
                paper_max,
            }
        })
        .collect()
}

/// Renders the comparison table.
pub fn render(rows: &[Table1Row]) -> String {
    let mut t = TextTable::new(&[
        "id", "configuration", "RMS (ours)", "paper MSE", "max err (ours)", "paper max",
    ]);
    for r in rows {
        t.row(vec![
            r.label.to_string(),
            r.config.clone(),
            sci(r.rms),
            sci(r.paper_mse),
            sci(r.max_err),
            sci(r.paper_max),
        ]);
    }
    format!(
        "TABLE I — configurations selected for analysis\n\
         (max input 6.0, 12-bit input precision, 15-bit output precision)\n\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_within_factor_two_of_paper() {
        // The reproduction criterion: same error band, same ordering.
        for r in compute() {
            assert!(
                r.max_err < 2.0 * r.paper_max && r.max_err > 0.3 * r.paper_max,
                "{}: ours {} vs paper {}",
                r.label,
                r.max_err,
                r.paper_max
            );
            assert!(
                r.rms < 2.0 * r.paper_mse && r.rms > 0.3 * r.paper_mse,
                "{}: rms {} vs paper {}",
                r.label,
                r.rms,
                r.paper_mse
            );
        }
    }

    #[test]
    fn render_contains_all_labels() {
        let text = render(&compute());
        for label in ["A ", "B1", "B2", "C ", "D ", "E "] {
            assert!(text.contains(label.trim()), "{label}");
        }
        assert!(text.contains("TABLE I"));
    }
}
