//! Table II — "Multi-bit lookup for velocity factors": the contents of
//! one paired-bit 4-to-1 mux entry, plus the concrete register file the
//! Table I velocity configuration stores.

use crate::approx::reference::velocity_factor;
use crate::approx::velocity::Velocity;
use crate::util::table::TextTable;

/// Renders the schematic Table II plus the concrete register values.
pub fn render(v: &Velocity) -> String {
    let mut t = TextTable::new(&["bits", "value"]);
    t.row(vec!["00".into(), "1.0".into()]);
    t.row(vec!["01".into(), "Velocity factor corresponding to lsb".into()]);
    t.row(vec!["10".into(), "Velocity factor corresponding to msb".into()]);
    t.row(vec!["11".into(), "Multiplication of velocity factors of lsb and msb".into()]);

    let mut regs = TextTable::new(&["k", "weight 2^k", "f = e^{2·2^k}", "stored (quantized)"]);
    let m = v.threshold_shift() as i32;
    for (i, k) in (-m..=v.kmax()).rev().enumerate() {
        let w = (2f64).powi(k);
        regs.row(vec![
            format!("{k}"),
            format!("{w}"),
            format!("{:.9}", velocity_factor(w)),
            format!("{:.9}", v.registers()[i].to_f64()),
        ]);
    }
    format!(
        "TABLE II — multi-bit lookup for velocity factors\n\n{}\n\
         Stored register file for {} ({} registers):\n\n{}",
        t.render(),
        v.describe_public(),
        v.register_count(),
        regs.render()
    )
}

impl Velocity {
    /// Public description helper (TanhApprox::describe without the
    /// trait import).
    pub fn describe_public(&self) -> String {
        use crate::approx::TanhApprox;
        self.describe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_schematic_and_registers() {
        let text = render(&Velocity::table1());
        assert!(text.contains("TABLE II"));
        assert!(text.contains("00"));
        assert!(text.contains("Multiplication of velocity factors"));
        // 10 registers for θ=1/128 (paper §IV.E)
        assert!(text.contains("10 registers"));
        // largest register e^{2·4} = e^8 ≈ 2980.958
        assert!(text.contains("2980.95"));
    }
}
