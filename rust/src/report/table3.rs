//! Table III — "Effect of input range and precision on approximation
//! parameters": cheapest parameter per method reaching ≤ 1 output ulp.

use crate::approx::MethodId;
use crate::error::{table3_rows, Table3Row, Table3Spec};
use crate::util::table::{step_str, TextTable};

/// Paper-reported Table III parameters, row-major (A, B1, B2, C, D, E).
/// Steps/thresholds as values, Lambert as term counts.
pub const PAPER_VALUES: [[f64; 6]; 4] = [
    [1.0 / 128.0, 1.0 / 32.0, 1.0 / 16.0, 1.0 / 16.0, 1.0 / 128.0, 6.0],
    [1.0 / 128.0, 1.0 / 32.0, 1.0 / 16.0, 1.0 / 64.0, 1.0 / 256.0, 6.0],
    [1.0 / 128.0, 1.0 / 32.0, 1.0 / 16.0, 1.0 / 64.0, 1.0 / 256.0, 8.0],
    [1.0 / 8.0, 1.0 / 32.0, 1.0 / 32.0, 1.0 / 8.0, 1.0 / 8.0, 4.0],
];

/// Computes all four rows (exhaustive 1-ulp searches).
pub fn compute(ulp_budget: f64) -> Vec<Table3Row> {
    table3_rows()
        .into_iter()
        .map(|spec| crate::error::ulp_search::compute_table3_row(spec, ulp_budget))
        .collect()
}

fn param_str(id: MethodId, p: Option<f64>) -> String {
    match p {
        None => "-".to_string(),
        Some(v) if id == MethodId::Lambert => format!("{}", v as u64),
        Some(v) => step_str(v),
    }
}

/// Renders ours-vs-paper.
pub fn render(rows: &[Table3Row]) -> String {
    let mut t = TextTable::new(&[
        "input", "output", "range", "A", "B1", "B2", "C", "D", "E", "paper(A..E)",
    ]);
    for (row, paper) in rows.iter().zip(PAPER_VALUES) {
        let mut cells = vec![
            format!("{}", row.spec.input),
            format!("{}", row.spec.output),
            format!("±{}", row.spec.range),
        ];
        for (i, id) in MethodId::all().into_iter().enumerate() {
            cells.push(param_str(id, row.params[i]));
        }
        let paper_cells: Vec<String> = MethodId::all()
            .into_iter()
            .enumerate()
            .map(|(i, id)| param_str(id, Some(paper[i])))
            .collect();
        cells.push(paper_cells.join(" "));
        t.row(cells);
    }
    format!(
        "TABLE III — effect of input range and precision on approximation\n\
         parameters (max error ≤ 1 ulp)\n\n{}",
        t.render()
    )
}

/// The module also re-exports the spec type for the CLI.
pub use crate::error::ulp_search::compute_table3_row;
pub type Spec = Table3Spec;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QFormat;

    #[test]
    fn eight_bit_row_shape_matches_paper() {
        // Row 4 (S2.5 → S.7 ±4): all methods pass with cheap parameters,
        // and the parameters are within 4× of the paper's.
        let spec = Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 };
        let row = compute_table3_row(spec, 1.0);
        let paper = PAPER_VALUES[3];
        for (i, id) in MethodId::all().into_iter().enumerate() {
            let got = row.params[i].unwrap_or(0.0);
            assert!(got > 0.0, "{id:?} found no passing parameter");
            if id == MethodId::Lambert {
                assert!(got <= paper[i] + 2.0, "{id:?}: {got} vs paper {}", paper[i]);
            } else {
                assert!(
                    got >= paper[i] / 4.0,
                    "{id:?}: {got} much finer than paper {}",
                    paper[i]
                );
            }
        }
    }

    #[test]
    fn render_has_four_rows() {
        // Use the cheap 8-bit spec only (full table is a bench, not a
        // unit test).
        let spec = Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 };
        let row = compute_table3_row(spec, 1.0);
        let text = render(&[row]);
        assert!(text.contains("TABLE III"));
        assert!(text.contains("S2.5"));
    }
}
