//! Netlist construction: a builder with *traced* fixed-point values.
//!
//! [`TFx`] and [`TWide`] are the netlist shadows of [`Fx`] and
//! [`crate::fixed::FxWide`]: a net id plus the format/fraction
//! bookkeeping. The arithmetic helpers on [`Builder`] replicate the
//! `fixed` substrate *operation by operation* — every place `Fx`
//! clamps, a [`CellKind::Clamp`] is emitted; every place `FxWide`
//! narrows, a rounding [`CellKind::Shr`] is emitted with the same
//! [`Round`] mode — so the elaborated graph computes bit-identical
//! words to the golden datapath models by construction.

use super::ir::{Cell, CellKind, Design, NetId};
use crate::fixed::{Fx, QFormat, Round};

/// Widths above the input port are bounded so pathological chains
/// cannot overflow the `u32` bookkeeping; `i128` simulation is exact
/// well past this.
const MAX_W: u32 = 120;

/// A traced [`Fx`]: a net known to hold an in-range raw word of
/// format `fmt`.
#[derive(Clone, Copy, Debug)]
pub struct TFx {
    /// The net carrying the raw word.
    pub net: NetId,
    /// Its fixed-point format.
    pub fmt: QFormat,
}

/// A traced [`crate::fixed::FxWide`]: a net holding an unclamped wide
/// word with `frac` fraction bits.
#[derive(Clone, Copy, Debug)]
pub struct TWide {
    /// The net carrying the wide word.
    pub net: NetId,
    /// Fraction bits of the wide word.
    pub frac: u32,
    /// Conservative width bound in bits (wire declaration / pricing).
    pub width: u32,
}

/// Incremental netlist builder enforcing the canonical net naming
/// (`cells[k].out == k + 1`, net 0 = input).
pub struct Builder {
    name: String,
    in_fmt: QFormat,
    out_fmt: QFormat,
    cells: Vec<Cell>,
    ranks: u32,
}

impl Builder {
    /// Starts a design; returns the builder and the input port as a
    /// traced value.
    pub fn new(name: &str, in_fmt: QFormat, out_fmt: QFormat) -> (Builder, TFx) {
        let b = Builder {
            name: name.to_string(),
            in_fmt,
            out_fmt,
            cells: Vec::new(),
            ranks: 0,
        };
        (b, TFx { net: 0, fmt: in_fmt })
    }

    /// Appends a cell; its output net is the next dense index.
    pub fn push(&mut self, kind: CellKind, inputs: Vec<NetId>, width: u32) -> NetId {
        let out = self.cells.len() + 1;
        self.cells.push(Cell { kind, inputs, out, width: width.clamp(1, MAX_W) });
        out
    }

    /// A constant word.
    pub fn konst(&mut self, value: i128, width: u32) -> NetId {
        self.push(CellKind::Const { value }, vec![], width)
    }

    /// A constant [`Fx`] as a traced value.
    pub fn fx_const(&mut self, v: Fx) -> TFx {
        let net = self.konst(v.raw() as i128, v.format().width());
        TFx { net, fmt: v.format() }
    }

    /// A constant wide word.
    pub fn wide_const(&mut self, raw: i128, frac: u32, width: u32) -> TWide {
        TWide { net: self.konst(raw, width), frac, width }
    }

    /// Marks a pipeline stage boundary. Callers then [`Builder::reg`]
    /// every live signal; `stages` becomes `ranks + 1` at
    /// [`Builder::finish`].
    pub fn rank(&mut self) {
        self.ranks += 1;
    }

    /// Registers a raw net (one flop bank of the current rank).
    pub fn reg_net(&mut self, n: NetId, width: u32) -> NetId {
        self.push(CellKind::Reg, vec![n], width)
    }

    /// Registers a traced [`Fx`].
    pub fn reg(&mut self, a: TFx) -> TFx {
        TFx { net: self.reg_net(a.net, a.fmt.width()), fmt: a.fmt }
    }

    /// Registers a traced wide word.
    pub fn reg_wide(&mut self, a: TWide) -> TWide {
        TWide { net: self.reg_net(a.net, a.width), frac: a.frac, width: a.width }
    }

    /// Registers a single-bit control net.
    pub fn reg_bit(&mut self, n: NetId) -> NetId {
        self.reg_net(n, 1)
    }

    /// Clamps a raw net to a format's representable range
    /// (`Fx::from_raw` saturation).
    pub fn clamp_to(&mut self, n: NetId, fmt: QFormat) -> NetId {
        self.push(
            CellKind::Clamp { lo: fmt.min_raw() as i128, hi: fmt.max_raw() as i128 },
            vec![n],
            fmt.width(),
        )
    }

    /// `Fx::convert`: align fraction bits (rounding on narrowing),
    /// then saturate to the destination range.
    pub fn convert(&mut self, a: TFx, dst: QFormat, round: Round) -> TFx {
        if a.fmt == dst {
            return a;
        }
        let (sf, df) = (a.fmt.frac_bits, dst.frac_bits);
        let shifted = if df >= sf {
            if df > sf {
                self.push(CellKind::Shl { sh: df - sf }, vec![a.net], a.fmt.width() + (df - sf))
            } else {
                a.net
            }
        } else {
            self.push(CellKind::Shr { sh: sf - df, mode: round }, vec![a.net], a.fmt.width())
        };
        TFx { net: self.clamp_to(shifted, dst), fmt: dst }
    }

    /// `fixed::fx_add`: convert both operands, add, saturate.
    pub fn fx_add(&mut self, a: TFx, b: TFx, dst: QFormat, round: Round) -> TFx {
        let a = self.convert(a, dst, round);
        let b = self.convert(b, dst, round);
        let s = self.push(CellKind::Add, vec![a.net, b.net], dst.width() + 1);
        TFx { net: self.clamp_to(s, dst), fmt: dst }
    }

    /// `fixed::fx_sub`.
    pub fn fx_sub(&mut self, a: TFx, b: TFx, dst: QFormat, round: Round) -> TFx {
        let a = self.convert(a, dst, round);
        let b = self.convert(b, dst, round);
        let s = self.push(CellKind::Sub, vec![a.net, b.net], dst.width() + 1);
        TFx { net: self.clamp_to(s, dst), fmt: dst }
    }

    /// `Fx::neg` (negate, saturate).
    pub fn neg(&mut self, a: TFx) -> TFx {
        let n = self.push(CellKind::Neg, vec![a.net], a.fmt.width() + 1);
        TFx { net: self.clamp_to(n, a.fmt), fmt: a.fmt }
    }

    /// `FxWide::from_fx` — free retagging.
    pub fn wide_from_fx(&self, a: TFx) -> TWide {
        TWide { net: a.net, frac: a.fmt.frac_bits, width: a.fmt.width() }
    }

    /// `fixed::fx_mul_wide`: full-width product, fractions add.
    pub fn mul_wide(&mut self, a: TFx, b: TFx) -> TWide {
        let width = a.fmt.width() + b.fmt.width();
        let net = self.push(CellKind::Mul, vec![a.net, b.net], width);
        TWide { net, frac: a.fmt.frac_bits + b.fmt.frac_bits, width }
    }

    /// `FxWide::add`: align the smaller fraction up, then add (exact,
    /// no saturation at wide precision).
    pub fn wide_add(&mut self, a: TWide, b: TWide) -> TWide {
        let frac = a.frac.max(b.frac);
        let an = self.wide_align(a, frac);
        let bn = self.wide_align(b, frac);
        let width = an.width.max(bn.width) + 1;
        let net = self.push(CellKind::Add, vec![an.net, bn.net], width);
        TWide { net, frac, width }
    }

    fn wide_align(&mut self, a: TWide, frac: u32) -> TWide {
        if frac == a.frac {
            return a;
        }
        let sh = frac - a.frac;
        let net = self.push(CellKind::Shl { sh }, vec![a.net], a.width + sh);
        TWide { net, frac, width: a.width + sh }
    }

    /// Wide negation (`FxWide::mul` by `{raw: -1, frac: 0}` in the
    /// golden Newton-Raphson code).
    pub fn wide_neg(&mut self, a: TWide) -> TWide {
        let net = self.push(CellKind::Neg, vec![a.net], a.width + 1);
        TWide { net, frac: a.frac, width: a.width + 1 }
    }

    /// `FxWide::narrow`: rounding shift to the destination fraction,
    /// then saturate to its range.
    pub fn narrow(&mut self, a: TWide, dst: QFormat, round: Round) -> TFx {
        let df = dst.frac_bits;
        let shifted = if a.frac >= df {
            if a.frac > df {
                self.push(CellKind::Shr { sh: a.frac - df, mode: round }, vec![a.net], a.width)
            } else {
                a.net
            }
        } else {
            self.push(CellKind::Shl { sh: df - a.frac }, vec![a.net], a.width + (df - a.frac))
        };
        TFx { net: self.clamp_to(shifted, dst), fmt: dst }
    }

    /// `fixed::fx_mul` = wide product + narrow.
    pub fn fx_mul(&mut self, a: TFx, b: TFx, dst: QFormat, round: Round) -> TFx {
        let w = self.mul_wide(a, b);
        self.narrow(w, dst, round)
    }

    /// Format-preserving 2-to-1 select (both arms must share `a.fmt`).
    pub fn mux(&mut self, sel: NetId, a: TFx, b: TFx) -> TFx {
        debug_assert_eq!(a.fmt, b.fmt, "mux arms must share a format");
        let net = self.push(CellKind::Mux, vec![sel, a.net, b.net], a.fmt.width());
        TFx { net, fmt: a.fmt }
    }

    /// Raw-net 2-to-1 select.
    pub fn mux_net(&mut self, sel: NetId, a: NetId, b: NetId, width: u32) -> NetId {
        self.push(CellKind::Mux, vec![sel, a, b], width)
    }

    /// Finalizes the design. The output must already be in the
    /// declared output format.
    pub fn finish(self, output: TFx) -> Design {
        debug_assert_eq!(output.fmt, self.out_fmt, "output format mismatch");
        let d = Design {
            name: self.name,
            in_fmt: self.in_fmt,
            out_fmt: self.out_fmt,
            stages: self.ranks + 1,
            output: output.net,
            cells: self.cells,
        };
        debug_assert!(d.validate().is_ok(), "{:?}", d.validate());
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{fx_add, fx_mul, FxWide};
    use crate::rtl::sim::eval_flush;

    /// The builder ops must match the fixed substrate bit-for-bit;
    /// here on a little add/mul/narrow chain over a dense input grid.
    #[test]
    fn traced_ops_match_fixed_substrate() {
        let in_fmt = QFormat::new(2, 5);
        let out_fmt = QFormat::new(0, 7);
        let c = Fx::from_f64(0.7, QFormat::new(1, 6));
        let (mut b, x) = Builder::new("t", in_fmt, out_fmt);
        let s = b.fx_add(x, x, QFormat::new(2, 5), Round::NearestAway);
        let cc = b.fx_const(c);
        let m = b.fx_mul(s, cc, QFormat::new(1, 6), Round::NearestEven);
        b.rank();
        let m = b.reg(m);
        let y = b.convert(m, out_fmt, Round::NearestAway);
        let d = b.finish(y);
        assert_eq!(d.stages, 2);
        for raw in in_fmt.min_raw()..=in_fmt.max_raw() {
            let x = Fx::from_raw(raw, in_fmt);
            let s = fx_add(x, x, QFormat::new(2, 5), Round::NearestAway);
            let m = fx_mul(s, c, QFormat::new(1, 6), Round::NearestEven);
            let want = m.convert(out_fmt, Round::NearestAway);
            assert_eq!(eval_flush(&d, raw), want.raw(), "raw={raw}");
        }
    }

    #[test]
    fn wide_add_aligns_fractions_like_fxwide() {
        let f1 = QFormat::new(1, 3);
        let f2 = QFormat::new(1, 6);
        let a = Fx::from_raw(5, f1);
        let c = Fx::from_raw(-17, f2);
        let (mut b, x) = Builder::new("w", f1, f2);
        let _ = x;
        let ta = b.fx_const(a);
        let tc = b.fx_const(c);
        let wa = b.wide_from_fx(ta);
        let wc = b.wide_from_fx(tc);
        let sum = b.wide_add(wa, wc);
        let y = b.narrow(sum, f2, Round::NearestAway);
        let d = b.finish(y);
        let want = FxWide::from_fx(a).add(FxWide::from_fx(c)).narrow(f2, Round::NearestAway);
        assert_eq!(eval_flush(&d, 0), want.raw());
    }
}
