//! The elaborator: lowers a [`MethodSpec`] to a structural netlist
//! [`Design`] mirroring the Fig 3/4/5 datapath arithmetic cell by
//! cell.
//!
//! Guard policy: elaboration first runs [`crate::hw::pipeline_for`],
//! so a spec the block diagrams cannot express fails here with the
//! *same* typed "unsupported by hw backend" message the hw lowering
//! produces — no second error vocabulary. The lowered pipeline's
//! latency then cross-checks the elaborated stage count: the netlist
//! registers exactly the ranks the cycle-accurate `Pipeline` has.
//!
//! Equivalence strategy: the pipeline's stage closures are opaque, so
//! instead of walking them this module re-derives each datapath from
//! the same golden configuration objects (`Pwl`, `Taylor`,
//! `CatmullRom`, `Velocity`, `Lambert`) using the [`Builder`]'s traced
//! ops — which replicate `fixed`'s convert/narrow/clamp semantics
//! exactly — and the property tests pin the chain netlist == pipeline
//! == golden kernel bit-exact over the full domain grids.

use super::build::{Builder, TFx};
use super::ir::{CellKind, Design, NetId};
use crate::approx::catmull_rom::CatmullRom;
use crate::approx::lambert::Lambert;
use crate::approx::newton::{NR_FMT, NR_ITERS};
use crate::approx::pwl::Pwl;
use crate::approx::taylor::Taylor;
use crate::approx::velocity::Velocity;
use crate::approx::{MethodParams, MethodSpec};
use crate::fixed::{Fx, QFormat, Round};

/// Internal format of the velocity-factor divider output T and the
/// 1 − T² refinement (mirrors the hw datapath's private constant).
const T_FMT: QFormat = QFormat::new(1, 24);

/// Elaborates a design point into a structural netlist. Errors with
/// the hw backend's own typed "unsupported" message for specs the
/// block diagrams cannot express.
pub fn elaborate(spec: &MethodSpec) -> Result<Design, String> {
    // Same guards, same wording, and the latency cross-check below.
    let pipe = crate::hw::pipeline_for(spec)?;
    let d = match spec.params {
        MethodParams::Pwl { step } => elab_pwl(spec, &pipe.name, step),
        MethodParams::Taylor { step, terms } => elab_taylor(spec, &pipe.name, step, terms),
        MethodParams::CatmullRom { step } => elab_catmull(spec, &pipe.name, step),
        MethodParams::Velocity { threshold } => elab_velocity(spec, &pipe.name, threshold),
        MethodParams::Lambert { terms } => elab_lambert(spec, &pipe.name, terms),
    };
    d.validate()?;
    if d.stages as usize != pipe.latency() {
        return Err(format!(
            "rtl elaboration of '{spec}' produced {} stages but the lowered pipeline \
             has {} — elaborator drift",
            d.stages,
            pipe.latency()
        ));
    }
    Ok(d)
}

/// Minimal signed width holding a constant.
fn const_width(v: i128) -> u32 {
    if v == 0 {
        1
    } else {
        129 - v.abs().leading_zeros()
    }
}

/// Shared front end (`sign_split_input`): sign bit, |x| with
/// saturation clamp, and the domain-saturation compare.
fn front_end(b: &mut Builder, x: TFx, domain: f64) -> (NetId, NetId, TFx) {
    let w = x.fmt.width();
    let neg = b.push(CellKind::IsNeg, vec![x.net], 1);
    let nx = b.push(CellKind::Neg, vec![x.net], w + 1);
    let ax = b.mux_net(neg, nx, x.net, w + 1);
    let mag = TFx { net: b.clamp_to(ax, x.fmt), fmt: x.fmt };
    // mag.to_f64() >= domain  ⇔  raw >= ceil(domain · 2^frac) (integer raw).
    let thresh = (domain * (1i64 << x.fmt.frac_bits) as f64).ceil() as i128;
    let tc = b.konst(thresh, const_width(thresh));
    let sat = b.push(CellKind::CmpGe, vec![mag.net, tc], 1);
    (neg, sat, mag)
}

/// Shared back end (`sign_merge_stage`): saturate, floor at zero,
/// restore the sign — in exactly the golden order.
fn sign_merge(b: &mut Builder, neg: NetId, sat: NetId, y: TFx, out: QFormat) -> TFx {
    debug_assert_eq!(y.fmt, out);
    let w = out.width();
    let maxv = b.konst(out.max_raw() as i128, w);
    let ym = b.mux_net(sat, maxv, y.net, w);
    let yneg = b.push(CellKind::IsNeg, vec![ym], 1);
    let zero = b.konst(0, w);
    let yz = b.mux_net(yneg, zero, ym, w);
    let ny = b.push(CellKind::Neg, vec![yz], w + 1);
    let nyc = b.clamp_to(ny, out);
    TFx { net: b.mux_net(neg, nyc, yz, w), fmt: out }
}

/// `UniformLut::split_index`: the index bit-field select and the
/// intra-segment fraction.
fn split_index(b: &mut Builder, mag: TFx, step: f64) -> (NetId, TFx) {
    let step_shift = (1.0 / step).log2() as u32;
    let t_bits = mag.fmt.frac_bits - step_shift;
    let idx =
        b.push(CellKind::Shr { sh: t_bits, mode: Round::Trunc }, vec![mag.net], mag.fmt.width());
    let mask = (1i128 << t_bits) - 1;
    let t_net = b.push(CellKind::And { mask }, vec![mag.net], t_bits.max(1));
    (idx, TFx { net: t_net, fmt: QFormat::new(0, t_bits) })
}

/// One LUT ROM over the golden entries.
fn rom(b: &mut Builder, entries: &[i64], addr: NetId, fmt: QFormat) -> TFx {
    let net =
        b.push(CellKind::Rom { entries: entries.to_vec() }, vec![addr], fmt.width());
    TFx { net, fmt }
}

// ---------------------------------------------------------------- PWL

fn elab_pwl(spec: &MethodSpec, name: &str, step: f64) -> Design {
    let g = Pwl::new(step, spec.domain);
    let out = spec.io.output;
    let (mut b, x) = Builder::new(name, spec.io.input, out);
    let (neg, sat, mag) = front_end(&mut b, x, spec.domain);

    // fetch: split index + parallel endpoint LUTs.
    let (idx, t) = split_index(&mut b, mag, step);
    let entries: Vec<i64> = (0..g.lut().len()).map(|i| g.lut().at(i).raw()).collect();
    let lut_fmt = g.lut().format();
    let y0 = rom(&mut b, &entries, idx, lut_fmt);
    let one = b.konst(1, 2);
    let idx1 = b.push(CellKind::Add, vec![idx, one], mag.fmt.width());
    let y1 = rom(&mut b, &entries, idx1, lut_fmt);
    b.rank();
    let (y0, y1, t) = (b.reg(y0), b.reg(y1), b.reg(t));
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // delta = Fx::from_raw(y1 - y0, lut_fmt).
    let dn = b.push(CellKind::Sub, vec![y1.net, y0.net], lut_fmt.width() + 1);
    let delta = TFx { net: b.clamp_to(dn, lut_fmt), fmt: lut_fmt };
    b.rank();
    let (delta, y0, t) = (b.reg(delta), b.reg(y0), b.reg(t));
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // multiply: wide delta × t product.
    let prod = b.mul_wide(delta, t);
    b.rank();
    let prod = b.reg_wide(prod);
    let y0 = b.reg(y0);
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // accumulate: y0 + prod, narrowed round-half-even.
    let y0w = b.wide_from_fx(y0);
    let acc = b.wide_add(y0w, prod);
    let y = b.narrow(acc, out, Round::NearestEven);
    b.rank();
    let y = b.reg(y);
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    let yf = sign_merge(&mut b, neg, sat, y, out);
    b.finish(yf)
}

// ------------------------------------------------------------- Taylor

fn elab_taylor(spec: &MethodSpec, name: &str, step: f64, terms: usize) -> Design {
    let g = Taylor::new(step, terms, spec.domain);
    let int = crate::approx::taylor::INT_FMT;
    let out = spec.io.output;
    let (mut b, x) = Builder::new(name, spec.io.input, out);
    let (neg, sat, mag) = front_end(&mut b, x, spec.domain);

    // fetch: split_fx — centered dx and the anchor LUT.
    let (idx, tfrac) = split_index(&mut b, mag, step);
    let t_bits = tfrac.fmt.frac_bits;
    let step_shift = (1.0 / step).log2() as u32;
    let half = b.konst(1i128 << (t_bits - 1), t_bits.max(1) + 1);
    let dxr = b.push(CellKind::Sub, vec![tfrac.net, half], t_bits + 2);
    let dx_fmt = QFormat::new(0, t_bits + step_shift);
    let dx = TFx { net: b.clamp_to(dxr, dx_fmt), fmt: dx_fmt };
    let entries: Vec<i64> = (0..g.lut().len()).map(|i| g.lut().at(i).raw()).collect();
    let anchor = rom(&mut b, &entries, idx, g.lut().format());
    b.rank();
    let (anchor, dx) = (b.reg(anchor), b.reg(dx));
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // coeff: coeffs_fx(anchor) — T, 1−T², c2, (c3).
    let t = b.convert(anchor, int, Round::NearestEven);
    let one = b.fx_const(Fx::from_raw(1i64 << 26, int));
    let t2 = b.fx_mul(t, t, int, Round::NearestAway);
    let d1 = b.fx_sub(one, t2, int, Round::NearestAway);
    let c2m = b.fx_mul(t, d1, int, Round::NearestAway);
    let c2 = b.neg(c2m);
    let c3 = if terms == 4 {
        let three = b.fx_const(Fx::from_f64(3.0, int));
        let tt2 = b.fx_mul(three, t2, int, Round::NearestAway);
        let gq = b.fx_sub(one, tt2, int, Round::NearestAway);
        let c3a = b.fx_mul(d1, gq, int, Round::NearestAway);
        let third = b.fx_const(Fx::from_f64(1.0 / 3.0, int));
        let c3b = b.fx_mul(c3a, third, int, Round::NearestAway);
        Some(b.neg(c3b))
    } else {
        None
    };
    b.rank();
    let (t, d1, dx) = (b.reg(t), b.reg(d1), b.reg(dx));
    let mut acc = b.reg(c2);
    let c3 = c3.map(|c| b.reg(c));
    let (mut neg, mut sat) = (b.reg_bit(neg), b.reg_bit(sat));
    let (mut t, mut d1, mut dx) = (t, d1, dx);

    // horner3 (cubic only): acc = dx·c3 + c2.
    if let Some(c3) = c3 {
        let w = b.mul_wide(dx, c3);
        let accw = b.wide_from_fx(acc);
        let s = b.wide_add(w, accw);
        let stepped = b.narrow(s, int, Round::NearestAway);
        b.rank();
        acc = b.reg(stepped);
        t = b.reg(t);
        d1 = b.reg(d1);
        dx = b.reg(dx);
        neg = b.reg_bit(neg);
        sat = b.reg_bit(sat);
    }

    // horner2: acc = dx·acc + d1.
    let w = b.mul_wide(dx, acc);
    let d1w = b.wide_from_fx(d1);
    let s = b.wide_add(w, d1w);
    let acc2 = b.narrow(s, int, Round::NearestAway);
    b.rank();
    let acc2 = b.reg(acc2);
    let t = b.reg(t);
    let dx = b.reg(dx);
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // horner1: y = dx·acc + T, narrowed round-half-even to the output.
    let w = b.mul_wide(dx, acc2);
    let tw = b.wide_from_fx(t);
    let s = b.wide_add(w, tw);
    let y = b.narrow(s, out, Round::NearestEven);
    b.rank();
    let y = b.reg(y);
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    let yf = sign_merge(&mut b, neg, sat, y, out);
    b.finish(yf)
}

// -------------------------------------------------------- Catmull-Rom

fn elab_catmull(spec: &MethodSpec, name: &str, step: f64) -> Design {
    let g = CatmullRom::new(step, spec.domain);
    let cr = crate::approx::catmull_rom::INT_FMT;
    let out = spec.io.output;
    let (mut b, x) = Builder::new(name, spec.io.input, out);
    let (neg, sat, mag) = front_end(&mut b, x, spec.domain);

    // fetch: the four control points around segment k = idx.
    let (idx, t) = split_index(&mut b, mag, step);
    let entries: Vec<i64> = (0..g.lut().len()).map(|i| g.lut().at(i).raw()).collect();
    let lut_fmt = g.lut().format();
    let zero = b.konst(0, 2);
    let sel0 = b.push(CellKind::CmpEq, vec![idx, zero], 1);
    let one = b.konst(1, 2);
    let im1 = b.push(CellKind::Sub, vec![idx, one], mag.fmt.width());
    let rm1 = rom(&mut b, &entries, im1, lut_fmt);
    // k = 0 reflects across the origin: p(−1) = −lut[1], a constant.
    let pm1 = b.konst(g.p(-1).raw() as i128, lut_fmt.width());
    let p0 = TFx { net: b.mux_net(sel0, pm1, rm1.net, lut_fmt.width()), fmt: lut_fmt };
    let p1 = rom(&mut b, &entries, idx, lut_fmt);
    let i1 = b.push(CellKind::Add, vec![idx, one], mag.fmt.width());
    let p2 = rom(&mut b, &entries, i1, lut_fmt);
    let two = b.konst(2, 3);
    let i2 = b.push(CellKind::Add, vec![idx, two], mag.fmt.width());
    let p3 = rom(&mut b, &entries, i2, lut_fmt);
    b.rank();
    let (p0, p1, p2, p3, t) = (b.reg(p0), b.reg(p1), b.reg(p2), b.reg(p3), b.reg(t));
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // t-vector: basis_fx(t).
    let tc = b.convert(t, cr, Round::NearestEven);
    let t2 = b.fx_mul(tc, tc, cr, Round::NearestAway);
    let t3 = b.fx_mul(t2, tc, cr, Round::NearestAway);
    let mut basis = |b: &mut Builder, terms: &[(TFx, f64)], plus_one: bool| -> TFx {
        let mut acc = None;
        for &(v, c) in terms {
            let cc = b.fx_const(Fx::from_f64(c, cr));
            let w = b.mul_wide(v, cc);
            acc = Some(match acc {
                None => w,
                Some(a) => b.wide_add(a, w),
            });
        }
        let mut acc = acc.expect("basis terms");
        if plus_one {
            let onec = b.fx_const(Fx::from_f64(1.0, cr));
            let onew = b.wide_from_fx(onec);
            acc = b.wide_add(acc, onew);
        }
        b.narrow(acc, cr, Round::NearestAway)
    };
    let b0 = basis(&mut b, &[(t3, -0.5), (t2, 1.0), (tc, -0.5)], false);
    let b1 = basis(&mut b, &[(t3, 1.5), (t2, -2.5)], true);
    let b2 = basis(&mut b, &[(t3, -1.5), (t2, 2.0), (tc, 0.5)], false);
    let b3 = basis(&mut b, &[(t3, 0.5), (t2, -0.5)], false);
    b.rank();
    let (b0, b1, b2, b3) = (b.reg(b0), b.reg(b1), b.reg(b2), b.reg(b3));
    let (p0, p1, p2, p3) = (b.reg(p0), b.reg(p1), b.reg(p2), b.reg(p3));
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // mac: Σ bᵢ·pᵢ at CR precision, narrowed round-half-even.
    let mut acc = None;
    for (bi, pi) in [(b0, p0), (b1, p1), (b2, p2), (b3, p3)] {
        let pc = b.convert(pi, cr, Round::NearestEven);
        let w = b.mul_wide(bi, pc);
        acc = Some(match acc {
            None => w,
            Some(a) => b.wide_add(a, w),
        });
    }
    let y = b.narrow(acc.expect("mac terms"), out, Round::NearestEven);
    b.rank();
    let y = b.reg(y);
    let (neg, sat) = (b.reg_bit(neg), b.reg_bit(sat));

    let yf = sign_merge(&mut b, neg, sat, y, out);
    b.finish(yf)
}

// ------------------------------------------- Newton-Raphson (shared)

/// `newton::normalize_den` as cells: MSB priority-encode, normalizing
/// barrel shift into Q1.30, and the one-step renormalization.
fn nl_normalize_den(b: &mut Builder, den: TFx) -> (TFx, NetId) {
    let p = b.push(CellKind::Msb, vec![den.net], 7);
    // e = p + 1 − frac_bits.
    let kc = b.konst(1 - den.fmt.frac_bits as i128, 8);
    let e0 = b.push(CellKind::Add, vec![p, kc], 8);
    // m_raw: shift so the MSB lands at bit 30 (amount = p − 29).
    let mant0 = b.push(
        CellKind::NormShift { base: -29, mode: Round::NearestAway },
        vec![den.net, p],
        NR_FMT.width(),
    );
    // Rounding can carry past 2^30: renormalize one step.
    let lim = b.konst(1i128 << 30, 32);
    let ge = b.push(CellKind::CmpGe, vec![mant0, lim], 1);
    let mant1 = b.push(CellKind::Shr { sh: 1, mode: Round::Trunc }, vec![mant0], NR_FMT.width());
    let mant = b.mux_net(ge, mant1, mant0, NR_FMT.width());
    let one = b.konst(1, 2);
    let e1 = b.push(CellKind::Add, vec![e0, one], 8);
    let e = b.mux_net(ge, e1, e0, 8);
    (TFx { net: mant, fmt: NR_FMT }, e)
}

/// `newton::nr_seed`: 48/17 − 32/17·m.
fn nl_nr_seed(b: &mut Builder, mant: TFx) -> TFx {
    let c1 = b.fx_const(Fx::from_f64(48.0 / 17.0, QFormat::new(2, 29)));
    let c2 = b.fx_const(Fx::from_f64(32.0 / 17.0, QFormat::new(2, 29)));
    let w = b.mul_wide(c2, mant);
    let wn = b.wide_neg(w);
    let c1w = b.wide_from_fx(c1);
    let s = b.wide_add(c1w, wn);
    b.narrow(s, NR_FMT, Round::NearestAway)
}

/// `newton::nr_step`: x·(2 − m·x).
fn nl_nr_step(b: &mut Builder, mant: TFx, x: TFx) -> TFx {
    let bx = b.mul_wide(mant, x);
    let bxn = b.wide_neg(bx);
    let two = b.wide_const(2i128 << 30, 30, 33);
    let s = b.wide_add(two, bxn);
    let corr = b.narrow(s, QFormat::new(2, 29), Round::NearestAway);
    let w = b.mul_wide(x, corr);
    b.narrow(w, NR_FMT, Round::NearestAway)
}

/// `newton::finish_div`: num·recip with the exponent-recovery
/// normalizing shift, saturated into `out`.
fn nl_finish_div(b: &mut Builder, num: TFx, recip: TFx, e: NetId, out: QFormat) -> TFx {
    let w = b.mul_wide(num, recip);
    let base = (w.frac - out.frac_bits) as i32;
    let ns = b.push(
        CellKind::NormShift { base, mode: Round::NearestAway },
        vec![w.net, e],
        w.width,
    );
    TFx { net: b.clamp_to(ns, out), fmt: out }
}

// ----------------------------------------------------------- Velocity

fn elab_velocity(spec: &MethodSpec, name: &str, threshold: f64) -> Design {
    let g = Velocity::new(threshold, spec.domain);
    let wf = g.wide_format();
    let m_shift = g.threshold_shift();
    let out = spec.io.output;
    let in_fmt = spec.io.input;
    let frac = in_fmt.frac_bits;
    let (mut b, x) = Builder::new(name, in_fmt, out);
    let (neg, sat, mag) = front_end(&mut b, x, spec.domain);

    // split: coarse bits ≥ θ and the sub-threshold residue.
    let res_bits = frac.saturating_sub(m_shift);
    let mask = (1i128 << res_bits) - 1;
    let residue = b.push(CellKind::And { mask }, vec![mag.net], res_bits.max(1));
    let coarse = b.push(CellKind::Sub, vec![mag.net, residue], in_fmt.width());
    let f0 = b.fx_const(Fx::one(wf));
    b.rank();
    let mut coarse = b.reg_net(coarse, in_fmt.width());
    let mut residue = b.reg_net(residue, res_bits.max(1));
    let mut f = b.reg(f0);
    let (mut neg, mut sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // vfmul chain: one conditional register multiply per stored factor.
    let ks: Vec<i32> = (-(m_shift as i32)..=g.kmax()).rev().collect();
    let nstages = ks.len();
    for (i, k) in ks.into_iter().enumerate() {
        let bitpos = k + frac as i32;
        if bitpos >= 0 {
            let sh = b.push(
                CellKind::Shr { sh: bitpos as u32, mode: Round::Trunc },
                vec![coarse],
                in_fmt.width(),
            );
            let bit = b.push(CellKind::And { mask: 1 }, vec![sh], 1);
            let vfc = b.fx_const(g.registers()[i]);
            let fm = b.fx_mul(f, vfc, wf, Round::NearestAway);
            f = b.mux(bit, fm, f);
        }
        b.rank();
        if i + 1 < nstages {
            coarse = b.reg_net(coarse, in_fmt.width());
        }
        residue = b.reg_net(residue, res_bits.max(1));
        f = b.reg(f);
        neg = b.reg_bit(neg);
        sat = b.reg_bit(sat);
    }

    // addsub: num = F − 1, den = F + 1.
    let one = b.fx_const(Fx::one(wf));
    let num = b.fx_sub(f, one, wf, Round::NearestAway);
    let den = b.fx_add(f, one, wf, Round::NearestAway);
    b.rank();
    let num = b.reg(num);
    let den = b.reg(den);
    residue = b.reg_net(residue, res_bits.max(1));
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // normalize den into Q1.30 mantissa × 2^e.
    let (mant, e) = nl_normalize_den(&mut b, den);
    b.rank();
    let mant = b.reg(mant);
    let mut e = b.reg_net(e, 8);
    let mut num = b.reg(num);
    residue = b.reg_net(residue, res_bits.max(1));
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // nr-seed.
    let seed = nl_nr_seed(&mut b, mant);
    b.rank();
    let mut recip = b.reg(seed);
    let mut mant = b.reg(mant);
    e = b.reg_net(e, 8);
    num = b.reg(num);
    residue = b.reg_net(residue, res_bits.max(1));
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // nr-iter × NR_ITERS.
    for it in 0..NR_ITERS {
        let next = nl_nr_step(&mut b, mant, recip);
        b.rank();
        recip = b.reg(next);
        if it + 1 < NR_ITERS {
            mant = b.reg(mant);
        }
        e = b.reg_net(e, 8);
        num = b.reg(num);
        residue = b.reg_net(residue, res_bits.max(1));
        neg = b.reg_bit(neg);
        sat = b.reg_bit(sat);
    }

    // recover: T = (F−1)/(F+1), with the exact-zero short circuit.
    let val = nl_finish_div(&mut b, num, recip, e, T_FMT);
    let zero = b.konst(0, 2);
    let numz = b.push(CellKind::CmpEq, vec![num.net, zero], 1);
    let tzero = b.fx_const(Fx::zero(T_FMT));
    let t = b.mux(numz, tzero, val);
    b.rank();
    let t = b.reg(t);
    residue = b.reg_net(residue, res_bits.max(1));
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // refine: y = T + b·(1 − T²), round-half-even into the output.
    let bfx = TFx { net: residue, fmt: QFormat::new(0, frac) };
    let t2 = b.fx_mul(t, t, T_FMT, Round::NearestAway);
    let onet = b.fx_const(Fx::one(T_FMT));
    let d1 = b.fx_sub(onet, t2, T_FMT, Round::NearestAway);
    let w = b.mul_wide(bfx, d1);
    let tw = b.wide_from_fx(t);
    let s = b.wide_add(w, tw);
    let y = b.narrow(s, out, Round::NearestEven);
    b.rank();
    let y = b.reg(y);
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    let yf = sign_merge(&mut b, neg, sat, y, out);
    b.finish(yf)
}

// ------------------------------------------------------------ Lambert

fn elab_lambert(spec: &MethodSpec, name: &str, k_terms: usize) -> Design {
    let g = Lambert::new(k_terms, spec.domain);
    let wf = g.wide_format();
    let kk = 2 * k_terms as i64 + 1;
    let out = spec.io.output;
    let (mut b, x) = Builder::new(name, spec.io.input, out);
    let (neg, sat, mag) = front_end(&mut b, x, spec.domain);

    // square: x², plus the recurrence seeds T₋₁ = 1, T₀ = 2K+1.
    let x2w = b.mul_wide(mag, mag);
    let x2 = b.narrow(x2w, wf, Round::NearestAway);
    let tm1_0 = b.fx_const(Fx::one(wf));
    let t0_0 = b.fx_const(Fx::from_f64(kk as f64, wf));
    b.rank();
    let mut x2 = b.reg(x2);
    let mut xk = b.reg(mag);
    let mut tm1 = b.reg(tm1_0);
    let mut t0 = b.reg(t0_0);
    let (mut neg, mut sat) = (b.reg_bit(neg), b.reg_bit(sat));

    // continued-fraction recurrence: Tₙ = c·Tₙ₋₁ + x²·Tₙ₋₂.
    for n in 1..=k_terms {
        let c = (kk - 2 * n as i64) as f64;
        let cfx = b.fx_const(Fx::from_f64(c, wf));
        let w1 = b.mul_wide(cfx, t0);
        let w2 = b.mul_wide(x2, tm1);
        let s = b.wide_add(w1, w2);
        let t = b.narrow(s, wf, Round::NearestAway);
        tm1 = t0;
        t0 = t;
        b.rank();
        if n < k_terms {
            x2 = b.reg(x2);
        }
        xk = b.reg(xk);
        tm1 = b.reg(tm1);
        t0 = b.reg(t0);
        neg = b.reg_bit(neg);
        sat = b.reg_bit(sat);
    }

    // numerator: num = x·T_{K−1}; a non-positive denominator flags the
    // out-of-range fallback.
    let num = b.fx_mul(xk, tm1, wf, Round::NearestAway);
    let den = t0;
    let one = b.konst(1, 2);
    let ge1 = b.push(CellKind::CmpGe, vec![den.net, one], 1);
    let bad = b.push(CellKind::Not, vec![ge1], 1);
    b.rank();
    let num = b.reg(num);
    let den = b.reg(den);
    let mut bad = b.reg_bit(bad);
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // normalize (with the bad-denominator constant fallback 0.5·2¹).
    let (mant_n, e_n) = nl_normalize_den(&mut b, den);
    let mant_bad = b.konst((1i64 << 29) as i128, NR_FMT.width());
    let mant = TFx {
        net: b.mux_net(bad, mant_bad, mant_n.net, NR_FMT.width()),
        fmt: NR_FMT,
    };
    let e_bad = b.konst(1, 2);
    let e = b.mux_net(bad, e_bad, e_n, 8);
    b.rank();
    let mant = b.reg(mant);
    let mut e = b.reg_net(e, 8);
    let mut num = b.reg(num);
    bad = b.reg_bit(bad);
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // nr-seed.
    let seed = nl_nr_seed(&mut b, mant);
    b.rank();
    let mut recip = b.reg(seed);
    let mut mant = b.reg(mant);
    e = b.reg_net(e, 8);
    num = b.reg(num);
    bad = b.reg_bit(bad);
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    // nr-iter × NR_ITERS.
    for it in 0..NR_ITERS {
        let next = nl_nr_step(&mut b, mant, recip);
        b.rank();
        recip = b.reg(next);
        if it + 1 < NR_ITERS {
            mant = b.reg(mant);
        }
        e = b.reg_net(e, 8);
        num = b.reg(num);
        bad = b.reg_bit(bad);
        neg = b.reg_bit(neg);
        sat = b.reg_bit(sat);
    }

    // finish: y = num/den (or the saturated maximum when flagged).
    let val = nl_finish_div(&mut b, num, recip, e, out);
    let maxv = b.fx_const(Fx::max(out));
    let y = b.mux(bad, maxv, val);
    b.rank();
    let y = b.reg(y);
    neg = b.reg_bit(neg);
    sat = b.reg_bit(sat);

    let yf = sign_merge(&mut b, neg, sat, y, out);
    b.finish(yf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::MethodSpec;

    #[test]
    fn table1_specs_elaborate_with_pipeline_latency() {
        for spec in MethodSpec::table1_all() {
            let d = elaborate(&spec).expect("Table I specs elaborate");
            let pipe = crate::hw::pipeline_for(&spec).unwrap();
            assert_eq!(d.stages as usize, pipe.latency(), "{spec}");
            assert_eq!(d.name, pipe.name, "{spec}");
            assert!(d.validate().is_ok(), "{spec}");
            // d − 1 register ranks, each holding ≥ 3 signals (a value
            // plus the neg/sat controls).
            assert!(d.reg_count() >= 3 * (pipe.latency() - 1), "{spec}");
        }
    }

    #[test]
    fn unsupported_specs_error_with_hw_wording() {
        use crate::approx::{IoSpec, MethodParams};
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = elaborate(&bogus).unwrap_err();
        assert!(err.contains("unsupported by hw backend"), "{err}");
        assert!(err.contains("Horner"), "{err}");
    }
}
