//! The structural netlist IR: nets, primitive cells, and the priced
//! design graph the elaborator produces and the Verilog printer /
//! parser round-trip.
//!
//! The IR is deliberately tiny — one module, one clock, one input word
//! and one output word — because every Fig 3/4/5 datapath is exactly
//! that shape. Nets are dense indices: net 0 is the input port, and
//! net `k` (k ≥ 1) is *defined* as the output of cell `k − 1`
//! (builder invariant, enforced by [`Design::validate`]). That makes
//! structural equality of two [`Design`]s (`==`, derived) the same
//! thing as cell/net graph isomorphism under the canonical naming,
//! which is what the Verilog round-trip test pins.
//!
//! Cells are two-valued (no X/Z) and wide: each net carries one signed
//! integer word (simulated as `i128`), not individual bits — the right
//! granularity for datapath RTL, and the same word-level semantics the
//! [`crate::fixed`] substrate defines. Rounding cells carry their
//! [`Round`] mode so the simulator can defer to the *same*
//! [`Round::shift_right`] the golden models use: the equivalence chain
//! is exact by construction, not by reimplementation.

use crate::cost::UnitLibrary;
use crate::fixed::{QFormat, Round};

/// Dense net index. Net 0 is the module input; net `k` (k ≥ 1) is the
/// output of cell `k − 1`.
pub type NetId = usize;

/// The primitive cell library. Word-level, two-valued, combinational
/// except [`CellKind::Reg`].
#[derive(Clone, Debug, PartialEq)]
pub enum CellKind {
    /// Constant word (no inputs).
    Const {
        /// The driven value.
        value: i128,
    },
    /// `a + b`.
    Add,
    /// `a - b`.
    Sub,
    /// `a * b` (full-width product).
    Mul,
    /// `-a`.
    Neg,
    /// `sel != 0 ? a : b` — inputs `[sel, a, b]`.
    Mux,
    /// `a >= b` (signed) → 1/0.
    CmpGe,
    /// `a == b` → 1/0.
    CmpEq,
    /// `a < 0` → 1/0 (the sign bit — free wiring).
    IsNeg,
    /// `a == 0 ? 1 : 0`.
    Not,
    /// Constant left shift.
    Shl {
        /// Shift amount in bits.
        sh: u32,
    },
    /// Constant *rounding* right shift — the hardware form of
    /// [`Round::shift_right`]. `Trunc` is free wiring; the nearest
    /// modes cost an increment adder.
    Shr {
        /// Shift amount in bits.
        sh: u32,
        /// Rounding mode applied to the discarded bits.
        mode: Round,
    },
    /// Bitwise AND with a constant mask (bit-field select — free).
    And {
        /// The mask.
        mask: i128,
    },
    /// Saturation to `[lo, hi]` — the [`crate::fixed::Fx`] range clamp.
    Clamp {
        /// Inclusive lower bound.
        lo: i128,
        /// Inclusive upper bound.
        hi: i128,
    },
    /// Hardwired LUT ROM (the paper's "bitmapping logic"): `addr` is
    /// clamped to `[0, entries.len() − 1]`, matching
    /// [`crate::approx::lut::UniformLut::at`]'s guard-entry clamp.
    Rom {
        /// The table contents (raw fixed-point words).
        entries: Vec<i64>,
    },
    /// Priority encoder: bit position of the highest set bit
    /// (`floor(log2 v)`); 0 for `v <= 0`.
    Msb,
    /// Variable normalizing shift — inputs `[value, exp]`: with
    /// `amount = base + exp`, rounding-shift right by `amount` when
    /// `amount >= 0`, else shift left by `−amount`. One barrel shifter
    /// implements both the mantissa normalization and the
    /// exponent-recovery shift of the Newton-Raphson divider.
    NormShift {
        /// Compile-time bias added to the runtime exponent.
        base: i32,
        /// Rounding mode for right shifts.
        mode: Round,
    },
    /// Stage-boundary register (D flip-flop bank, `q <= d`).
    Reg,
}

impl CellKind {
    /// Stable printer/parser mnemonic (the `tv_<kind>` instance name).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            CellKind::Const { .. } => "const",
            CellKind::Add => "add",
            CellKind::Sub => "sub",
            CellKind::Mul => "mul",
            CellKind::Neg => "neg",
            CellKind::Mux => "mux",
            CellKind::CmpGe => "cmpge",
            CellKind::CmpEq => "cmpeq",
            CellKind::IsNeg => "isneg",
            CellKind::Not => "not",
            CellKind::Shl { .. } => "shl",
            CellKind::Shr { .. } => "shr",
            CellKind::And { .. } => "and",
            CellKind::Clamp { .. } => "clamp",
            CellKind::Rom { .. } => "rom",
            CellKind::Msb => "msb",
            CellKind::NormShift { .. } => "normshift",
            CellKind::Reg => "reg",
        }
    }
}

/// One instantiated primitive: kind, input nets, output net, and the
/// output word width in bits (used for wire declarations and the
/// area/delay pricing).
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    /// What the cell computes.
    pub kind: CellKind,
    /// Input nets, in positional order (see [`CellKind`] docs).
    pub inputs: Vec<NetId>,
    /// The single output net (always `cell index + 1`).
    pub out: NetId,
    /// Output word width in bits.
    pub width: u32,
}

/// An elaborated datapath: the cell graph plus the pipeline metadata
/// needed to run and price it. Derived `PartialEq` is structural
/// identity under the canonical net naming — the round-trip test's
/// isomorphism check.
#[derive(Clone, Debug, PartialEq)]
pub struct Design {
    /// Module name (matches the lowered pipeline's name).
    pub name: String,
    /// Input port format.
    pub in_fmt: QFormat,
    /// Output port format.
    pub out_fmt: QFormat,
    /// Pipeline depth in cycles: the number of combinational segments
    /// (register ranks + 1), equal to the lowered pipeline's latency.
    pub stages: u32,
    /// The net driving the output port.
    pub output: NetId,
    /// All cells, in topological creation order.
    pub cells: Vec<Cell>,
}

impl Design {
    /// Total net count (input net + one per cell).
    pub fn net_count(&self) -> usize {
        self.cells.len() + 1
    }

    /// Number of register (stage-boundary flop) cells.
    pub fn reg_count(&self) -> usize {
        self.cells.iter().filter(|c| matches!(c.kind, CellKind::Reg)).count()
    }

    /// Checks the structural invariants the builder guarantees:
    /// `cells[k].out == k + 1`, every input net already defined
    /// (topological order), and the output net in range.
    pub fn validate(&self) -> Result<(), String> {
        for (i, c) in self.cells.iter().enumerate() {
            if c.out != i + 1 {
                return Err(format!("cell {i} drives net {} (want {})", c.out, i + 1));
            }
            for &n in &c.inputs {
                if n > i {
                    return Err(format!("cell {i} reads undefined net {n}"));
                }
            }
            if c.width == 0 || c.width > 127 {
                return Err(format!("cell {i} has width {}", c.width));
            }
        }
        if self.output >= self.net_count() {
            return Err(format!("output net {} out of range", self.output));
        }
        Ok(())
    }

    /// Gate-equivalent area of one cell under the unit library.
    pub fn cell_area(lib: &UnitLibrary, cell: &Cell) -> f64 {
        let w = cell.width;
        match &cell.kind {
            // Pure wiring: constants, bit selects, constant shifts.
            CellKind::Const { .. } | CellKind::Shl { .. } | CellKind::And { .. } => 0.0,
            CellKind::IsNeg => 0.0,
            // Truncation is wiring; nearest rounding needs the
            // increment adder on the kept bits.
            CellKind::Shr { mode, .. } => {
                if *mode == Round::Trunc {
                    0.0
                } else {
                    lib.adder_area(w)
                }
            }
            CellKind::Add | CellKind::Sub | CellKind::Neg => lib.adder_area(w),
            // Saturation: two comparisons folded into one adder-class
            // block plus the select muxes.
            CellKind::Clamp { .. } => lib.adder_area(w) + lib.mux2_ge_per_bit * w as f64,
            CellKind::CmpGe | CellKind::CmpEq => lib.adder_area(w),
            // A w-bit product has ~w/2-bit operands in this IR (the
            // cell width is the full product width).
            CellKind::Mul => lib.mult_area(operand_bits(w)),
            CellKind::Mux => lib.mux2_ge_per_bit * w as f64,
            CellKind::Not => lib.mux2_ge_per_bit,
            CellKind::Rom { entries } => lib.lut_area(entries.len(), w),
            CellKind::Msb => lib.shifter_area(w),
            CellKind::NormShift { mode, .. } => {
                let round = if *mode == Round::Trunc { 0.0 } else { lib.adder_area(w) };
                lib.shifter_area(w) + round
            }
            CellKind::Reg => lib.reg_ge_per_bit * w as f64,
        }
    }

    /// Unit (FO4) delay through one cell.
    pub fn cell_delay(lib: &UnitLibrary, cell: &Cell) -> f64 {
        let w = cell.width;
        match &cell.kind {
            CellKind::Const { .. }
            | CellKind::Shl { .. }
            | CellKind::And { .. }
            | CellKind::IsNeg => 0.0,
            CellKind::Shr { mode, .. } => {
                if *mode == Round::Trunc {
                    0.0
                } else {
                    lib.adder_delay(w)
                }
            }
            CellKind::Add | CellKind::Sub | CellKind::Neg | CellKind::Clamp { .. } => {
                lib.adder_delay(w)
            }
            CellKind::CmpGe | CellKind::CmpEq => lib.adder_delay(w),
            CellKind::Mul => lib.mult_delay(operand_bits(w)),
            CellKind::Mux | CellKind::Not => 1.0,
            CellKind::Rom { entries } => lib.lut_delay(entries.len()),
            CellKind::Msb => 1.0 + (w.max(2) as f64).log2(),
            CellKind::NormShift { mode, .. } => {
                let round = if *mode == Round::Trunc { 0.0 } else { lib.adder_delay(w) };
                1.0 + (w.max(2) as f64).log2() + round
            }
            CellKind::Reg => 0.0,
        }
    }

    /// Summed gate-equivalent area over every instantiated cell
    /// (including the register ranks).
    pub fn area_ge(&self, lib: &UnitLibrary) -> f64 {
        self.cells.iter().map(|c| Design::cell_area(lib, c)).sum()
    }

    /// Longest register-to-register combinational path (FO4): dynamic
    /// programming over the topological creation order, with register
    /// outputs restarting the path at depth 0.
    pub fn critical_delay(&self, lib: &UnitLibrary) -> f64 {
        let mut depth = vec![0.0f64; self.net_count()];
        let mut worst = 0.0f64;
        for c in &self.cells {
            let arrive = c.inputs.iter().map(|&n| depth[n]).fold(0.0f64, f64::max);
            depth[c.out] = match c.kind {
                // The path ends at the register's D input…
                CellKind::Reg => 0.0,
                _ => arrive + Design::cell_delay(lib, c),
            };
            // …so account it before restarting.
            if matches!(c.kind, CellKind::Reg) {
                worst = worst.max(arrive);
            } else {
                worst = worst.max(depth[c.out]);
            }
        }
        worst
    }
}

/// Operand width of a full-width product cell (see [`CellKind::Mul`]).
fn operand_bits(product_width: u32) -> u32 {
    ((product_width + 1) / 2).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Design {
        // x -> +1 -> reg -> clamp -> y
        Design {
            name: "tiny".into(),
            in_fmt: QFormat::new(3, 12),
            out_fmt: QFormat::new(3, 12),
            stages: 2,
            output: 4,
            cells: vec![
                Cell { kind: CellKind::Const { value: 1 }, inputs: vec![], out: 1, width: 2 },
                Cell { kind: CellKind::Add, inputs: vec![0, 1], out: 2, width: 17 },
                Cell { kind: CellKind::Reg, inputs: vec![2], out: 3, width: 17 },
                Cell {
                    kind: CellKind::Clamp { lo: -4096, hi: 4095 },
                    inputs: vec![3],
                    out: 4,
                    width: 16,
                },
            ],
        }
    }

    #[test]
    fn validate_accepts_canonical_and_rejects_broken() {
        let d = tiny();
        assert!(d.validate().is_ok());
        let mut bad = d.clone();
        bad.cells[1].inputs = vec![5];
        assert!(bad.validate().is_err());
        let mut bad2 = d.clone();
        bad2.cells[2].out = 9;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn pricing_is_positive_and_registers_cut_the_critical_path() {
        let lib = UnitLibrary::default();
        let d = tiny();
        assert!(d.area_ge(&lib) > 0.0);
        // With the register between them, the worst segment is
        // max(add, clamp), not their sum.
        let add_d = Design::cell_delay(&lib, &d.cells[1]);
        let clamp_d = Design::cell_delay(&lib, &d.cells[3]);
        let crit = d.critical_delay(&lib);
        assert!((crit - add_d.max(clamp_d)).abs() < 1e-9, "crit {crit}");
        // Remove the register: the path is now the sum.
        let mut flat = d.clone();
        flat.cells[2].kind = CellKind::Shl { sh: 0 };
        assert!((flat.critical_delay(&lib) - (add_d + clamp_d)).abs() < 1e-9);
    }

    #[test]
    fn reg_count_counts_only_registers() {
        assert_eq!(tiny().reg_count(), 1);
    }
}
