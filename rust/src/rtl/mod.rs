//! RTL netlist subsystem: the structural tier below the hw pipeline.
//!
//! The hw backend lowers every supported spec into a cycle-accurate
//! [`crate::hw::Pipeline`] whose stages are opaque Rust closures —
//! faithful in timing and arithmetic, but with no *structure* to
//! price or print. This module closes the loop down to cells:
//!
//! - [`elaborate`] lowers the same design points into a [`Design`] —
//!   a flat netlist of arithmetic cells ([`CellKind`]) over numbered
//!   nets, with explicit register ranks at the stage boundaries.
//! - [`sim`] evaluates a netlist either flushed ([`eval_flush`], the
//!   raw→raw transfer function) or clocked ([`simulate`],
//!   cycle-accurate with simultaneous rank latching).
//! - [`verilog`] prints the netlist as structural Verilog — one
//!   printer for all six datapaths — and parses our own emission back
//!   ([`verilog::parse`]), so the round trip is checked for exact
//!   cell/net isomorphism.
//! - [`NetlistProbe`] prices the elaborated structure cell by cell
//!   (summed GE area, longest combinational path between ranks) and
//!   serves it through [`CostProbe`] as the `netlist` cost tier —
//!   `explore --backend hw --cost netlist` on the CLI.
//!
//! The equivalence chain is pinned by tests, bit-exact on raw words
//! over the full Table I domain grids: netlist flush == netlist
//! clocked == hw pipeline == golden kernel. The probe additionally
//! audits a strided slice of that chain on every cost query, so a
//! drifted netlist can never be priced silently.

pub mod build;
pub mod elab;
pub mod ir;
pub mod sim;
pub mod verilog;

pub use elab::elaborate;
pub use ir::{Cell, CellKind, Design, NetId};
pub use sim::{eval_flush, simulate};

use crate::approx::MethodSpec;
use crate::backend::{BackendError, CostProbe, CostSource, DesignCost};
use crate::cost::UnitLibrary;

/// Number of strided audit points the probe replays through the
/// golden kernel before pricing a netlist.
const AUDIT_PROBES: i64 = 251;

/// Prices design points off their elaborated RTL netlist.
///
/// `probe_cost` errors `unknown_spec` for specs the block diagrams
/// cannot express (so explorer fallbacks stay labeled `analytic`),
/// and errors `internal` if the elaborated netlist disagrees with the
/// golden kernel on any audit point — a mispriced netlist is a bug,
/// not a cost.
pub struct NetlistProbe {
    lib: UnitLibrary,
}

impl NetlistProbe {
    pub fn new() -> NetlistProbe {
        NetlistProbe { lib: UnitLibrary::default() }
    }
}

impl Default for NetlistProbe {
    fn default() -> Self {
        NetlistProbe::new()
    }
}

impl CostProbe for NetlistProbe {
    fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError> {
        let design = elaborate(spec).map_err(BackendError::unknown_spec)?;
        let kernel = crate::backend::golden_kernel(spec)?;
        // Strided audit across the full input range: the netlist must
        // reproduce the golden kernel bit-exact before it is priced.
        let (lo, hi) = (spec.io.input.min_raw(), spec.io.input.max_raw());
        let stride = ((hi - lo) / (AUDIT_PROBES - 1)).max(1);
        let mut x = lo;
        while x <= hi {
            let got = eval_flush(&design, x);
            let want = kernel.eval_raw(x);
            if got != want {
                return Err(BackendError::internal(format!(
                    "netlist for '{spec}' disagrees with the golden kernel at raw \
                     {x}: netlist {got}, golden {want}"
                )));
            }
            x += stride;
        }
        Ok(DesignCost {
            source: CostSource::Netlist,
            latency_cycles: design.stages,
            stage_delay_fo4: design.critical_delay(&self.lib),
            area_ge: design.area_ge(&self.lib),
            cycles_per_element: 1.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{MethodId, MethodSpec};
    use crate::backend::ErrorCode;

    #[test]
    fn probe_prices_table1_rows_with_netlist_provenance() {
        let probe = NetlistProbe::new();
        for spec in MethodSpec::table1_all() {
            let cost = probe.probe_cost(&spec).expect("Table I rows elaborate");
            assert_eq!(cost.source, CostSource::Netlist, "{spec}");
            assert!(cost.area_ge > 0.0, "{spec}: zero netlist area");
            assert!(cost.stage_delay_fo4 > 0.0, "{spec}: zero critical path");
            assert!(cost.latency_cycles > 0, "{spec}");
            assert_eq!(cost.cycles_per_element, 1.0, "{spec}");
        }
    }

    #[test]
    fn probe_rejects_unsupported_specs_as_unknown() {
        let probe = NetlistProbe::new();
        let bogus = MethodSpec {
            params: crate::approx::MethodParams::Lambert { terms: 40 },
            io: crate::approx::IoSpec::table1(),
            domain: 6.0,
        };
        let err = probe.probe_cost(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("unsupported by hw backend"), "{err}");
    }

    #[test]
    fn netlist_latency_matches_the_measured_pipeline() {
        let probe = NetlistProbe::new();
        let spec = MethodSpec::table1(MethodId::Pwl);
        let cost = probe.probe_cost(&spec).unwrap();
        let pipe = crate::hw::pipeline_for(&spec).unwrap();
        assert_eq!(cost.latency_cycles as usize, pipe.latency());
    }
}
