//! Two-valued netlist simulation: a flush (combinational) evaluator
//! and a cycle-accurate clocked evaluator over the registered stage
//! boundaries.
//!
//! Both simulators walk the cells in creation order — the builder
//! guarantees that order is topological — and both defer every
//! rounding decision to [`Round::shift_right`], the *same* function
//! the golden fixed-point models call. The equivalence chain
//! (netlist == pipeline == golden kernel) is therefore exact by
//! construction wherever the elaborated cell graph mirrors the golden
//! arithmetic, and the property tests pin that it does.

use super::ir::{Cell, CellKind, Design};
use crate::fixed::Round;

/// Evaluates one combinational cell given its input values.
fn eval_cell(cell: &Cell, vals: &[i128]) -> i128 {
    let a = |i: usize| vals[cell.inputs[i]];
    match &cell.kind {
        CellKind::Const { value } => *value,
        CellKind::Add => a(0) + a(1),
        CellKind::Sub => a(0) - a(1),
        CellKind::Mul => a(0) * a(1),
        CellKind::Neg => -a(0),
        CellKind::Mux => {
            if a(0) != 0 {
                a(1)
            } else {
                a(2)
            }
        }
        CellKind::CmpGe => (a(0) >= a(1)) as i128,
        CellKind::CmpEq => (a(0) == a(1)) as i128,
        CellKind::IsNeg => (a(0) < 0) as i128,
        CellKind::Not => (a(0) == 0) as i128,
        CellKind::Shl { sh } => a(0) << sh,
        CellKind::Shr { sh, mode } => mode.shift_right(a(0), *sh),
        CellKind::And { mask } => a(0) & mask,
        CellKind::Clamp { lo, hi } => a(0).clamp(*lo, *hi),
        CellKind::Rom { entries } => {
            // Negative addresses only occur on speculative (muxed-out)
            // paths; clamp both ends like UniformLut::at's guard.
            let idx = a(0).clamp(0, entries.len() as i128 - 1) as usize;
            entries[idx] as i128
        }
        CellKind::Msb => {
            let v = a(0);
            if v <= 0 {
                0
            } else {
                (127 - v.leading_zeros()) as i128
            }
        }
        CellKind::NormShift { base, mode } => {
            let amount = *base + a(1) as i32;
            if amount >= 0 {
                mode.shift_right(a(0), amount as u32)
            } else {
                a(0) << ((-amount) as u32)
            }
        }
        CellKind::Reg => unreachable!("Reg handled by the caller"),
    }
}

/// Flush evaluation: registers become wires and the whole design is
/// evaluated combinationally for one input word. This is the netlist's
/// `raw → raw` transfer function — what the equivalence tests compare
/// against `Pipeline::eval` and the golden kernel.
pub fn eval_flush(design: &Design, x: i64) -> i64 {
    let mut vals = vec![0i128; design.net_count()];
    vals[0] = x as i128;
    for cell in &design.cells {
        vals[cell.out] = match cell.kind {
            CellKind::Reg => vals[cell.inputs[0]],
            _ => eval_cell(cell, &vals),
        };
    }
    vals[design.output] as i64
}

/// Cycle-accurate clocked simulation: feeds one input per cycle,
/// latches every register rank simultaneously at each clock edge, and
/// returns the outputs plus the cycle count (`stages + n − 1`, the
/// fully pipelined schedule). Bit-exact with [`eval_flush`] per input
/// — the cross-check the tests pin.
pub fn simulate(design: &Design, xs: &[i64]) -> (Vec<i64>, u64) {
    let n = xs.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    let stages = design.stages as usize;
    let cycles = stages + n - 1;
    let mut vals = vec![0i128; design.net_count()];
    let mut out = Vec::with_capacity(n);
    let regs: Vec<usize> = design
        .cells
        .iter()
        .enumerate()
        .filter(|(_, c)| matches!(c.kind, CellKind::Reg))
        .map(|(i, _)| i)
        .collect();
    for cycle in 0..cycles {
        // Clock edge: snapshot every D input first, then latch — a
        // rank feeding the next rank directly must not shoot through.
        let next: Vec<i128> =
            regs.iter().map(|&i| vals[design.cells[i].inputs[0]]).collect();
        for (&i, v) in regs.iter().zip(next) {
            vals[design.cells[i].out] = v;
        }
        // Drive the input port (zeros once the stream drains).
        vals[0] = if cycle < n { xs[cycle] as i128 } else { 0 };
        // Propagate the combinational cells.
        for cell in &design.cells {
            if !matches!(cell.kind, CellKind::Reg) {
                vals[cell.out] = eval_cell(cell, &vals);
            }
        }
        if cycle + 1 >= stages {
            out.push(vals[design.output] as i64);
        }
    }
    (out, cycles as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QFormat;

    /// y = clamp(x + 1) behind one register rank (2 stages).
    fn incr_design() -> Design {
        Design {
            name: "incr".into(),
            in_fmt: QFormat::new(3, 12),
            out_fmt: QFormat::new(3, 12),
            stages: 2,
            output: 4,
            cells: vec![
                Cell { kind: CellKind::Const { value: 1 }, inputs: vec![], out: 1, width: 2 },
                Cell { kind: CellKind::Reg, inputs: vec![0], out: 2, width: 16 },
                Cell { kind: CellKind::Add, inputs: vec![2, 1], out: 3, width: 17 },
                Cell {
                    kind: CellKind::Clamp { lo: -4096, hi: 4095 },
                    inputs: vec![3],
                    out: 4,
                    width: 16,
                },
            ],
        }
    }

    #[test]
    fn flush_and_clocked_agree_with_pipelined_cycle_count() {
        let d = incr_design();
        let xs: Vec<i64> = vec![0, 5, -7, 4094, 4095, -4096];
        let (ys, cycles) = simulate(&d, &xs);
        assert_eq!(cycles, d.stages as u64 + xs.len() as u64 - 1);
        assert_eq!(ys.len(), xs.len());
        for (&x, &y) in xs.iter().zip(&ys) {
            assert_eq!(y, eval_flush(&d, x), "x={x}");
            assert_eq!(y, (x + 1).min(4095), "x={x}");
        }
    }

    #[test]
    fn rounding_cells_defer_to_round_shift_right() {
        for (mode, want) in
            [(Round::Trunc, 2), (Round::NearestAway, 3), (Round::NearestEven, 2)]
        {
            let d = Design {
                name: "shr".into(),
                in_fmt: QFormat::new(3, 12),
                out_fmt: QFormat::new(3, 12),
                stages: 1,
                output: 1,
                cells: vec![Cell {
                    kind: CellKind::Shr { sh: 1, mode },
                    inputs: vec![0],
                    out: 1,
                    width: 16,
                }],
            };
            assert_eq!(eval_flush(&d, 5), want, "{mode:?}");
        }
    }

    #[test]
    fn normshift_matches_the_shift_identity() {
        // NormShift(base=-2)(v, e): amount = e - 2.
        let d = Design {
            name: "ns".into(),
            in_fmt: QFormat::new(6, 8),
            out_fmt: QFormat::new(6, 8),
            stages: 1,
            output: 2,
            cells: vec![
                Cell { kind: CellKind::Const { value: 3 }, inputs: vec![], out: 1, width: 4 },
                Cell {
                    kind: CellKind::NormShift { base: -2, mode: Round::NearestAway },
                    inputs: vec![0, 1],
                    out: 2,
                    width: 16,
                },
            ],
        };
        // amount = 1: 13 >> 1 rounding away = 7.
        assert_eq!(eval_flush(&d, 13), 7);
    }

    #[test]
    fn msb_is_floor_log2_and_zero_for_nonpositive() {
        let d = Design {
            name: "msb".into(),
            in_fmt: QFormat::new(6, 8),
            out_fmt: QFormat::new(6, 8),
            stages: 1,
            output: 1,
            cells: vec![Cell { kind: CellKind::Msb, inputs: vec![0], out: 1, width: 7 }],
        };
        assert_eq!(eval_flush(&d, 1), 0);
        assert_eq!(eval_flush(&d, 2), 1);
        assert_eq!(eval_flush(&d, 255), 7);
        assert_eq!(eval_flush(&d, 0), 0);
        assert_eq!(eval_flush(&d, -9), 0);
    }
}
