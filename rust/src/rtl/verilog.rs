//! Structural Verilog round trip: a deterministic printer from the
//! netlist IR and a line-oriented parser that re-reads our own
//! emission back into a [`Design`].
//!
//! One printer serves all six datapaths — the IR is the single source
//! of truth, so `hw verilog` output can no longer drift from the
//! simulated pipeline. The parser is deliberately narrow: it consumes
//! exactly the shape `emit` produces (one cell instance per line,
//! `n<k>` net names, `u<i>` instance names, per-ROM case modules) and
//! the round-trip test `parse(&emit(d)) == d` is the cell/net
//! isomorphism check — both sides use the derived structural equality
//! on [`Design`].
//!
//! Layout of an emission:
//!
//! ```text
//! // tanh-vlsi rtl netlist          header: name/in/out/stages/cells
//! module tanh_rtl (clk, x, y);      one instance per IR cell
//!   ...
//! endmodule
//! module tv_rom_c<i> (addr, data);  one case-arm module per ROM cell
//! module tv_add ...                 behavioral reference primitives
//! ```

use super::ir::{Cell, CellKind, Design};
use crate::fixed::{QFormat, Round};
use std::fmt::Write as _;

/// Stable wire encoding of a rounding mode.
fn mode_code(mode: Round) -> u8 {
    match mode {
        Round::Trunc => 0,
        Round::NearestAway => 1,
        Round::NearestEven => 2,
    }
}

fn mode_parse(code: i128) -> Result<Round, String> {
    match code {
        0 => Ok(Round::Trunc),
        1 => Ok(Round::NearestAway),
        2 => Ok(Round::NearestEven),
        other => Err(format!("bad MODE code {other}")),
    }
}

/// Signed sized Verilog literal for a ROM entry.
fn rom_literal(v: i64, width: u32) -> String {
    if v < 0 {
        format!("-{width}'sd{}", v.unsigned_abs())
    } else {
        format!("{width}'sd{v}")
    }
}

fn wire_decl(net: usize, width: u32) -> String {
    if width == 1 {
        format!("  wire n{net};")
    } else {
        format!("  wire signed [{}:0] n{net};", width - 1)
    }
}

/// Emits the design as structural Verilog.
pub fn emit(d: &Design) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "// tanh-vlsi rtl netlist");
    let _ = writeln!(s, "// name: {}", d.name);
    let _ = writeln!(s, "// in: {}", d.in_fmt);
    let _ = writeln!(s, "// out: {}", d.out_fmt);
    let _ = writeln!(s, "// stages: {}", d.stages);
    let _ = writeln!(s, "// cells: {}", d.cells.len());
    let _ = writeln!(s, "module tanh_rtl (clk, x, y);");
    let _ = writeln!(s, "  input wire clk;");
    let _ = writeln!(s, "  input wire signed [{}:0] x;", d.in_fmt.width() - 1);
    let _ = writeln!(s, "  output wire signed [{}:0] y;", d.out_fmt.width() - 1);
    let _ = writeln!(s, "{}", wire_decl(0, d.in_fmt.width()));
    for cell in &d.cells {
        let _ = writeln!(s, "{}", wire_decl(cell.out, cell.width));
    }
    let _ = writeln!(s, "  assign n0 = x;");
    for (i, cell) in d.cells.iter().enumerate() {
        let w = cell.width;
        let line = match &cell.kind {
            CellKind::Const { value } => format!(
                "tv_const #(.W({w}), .V({value})) u{i} (.y(n{}));",
                cell.out
            ),
            CellKind::Add | CellKind::Sub | CellKind::Mul | CellKind::CmpGe | CellKind::CmpEq => {
                format!(
                    "tv_{} #(.W({w})) u{i} (.a(n{}), .b(n{}), .y(n{}));",
                    cell.kind.mnemonic(),
                    cell.inputs[0],
                    cell.inputs[1],
                    cell.out
                )
            }
            CellKind::Neg | CellKind::IsNeg | CellKind::Not | CellKind::Msb => format!(
                "tv_{} #(.W({w})) u{i} (.a(n{}), .y(n{}));",
                cell.kind.mnemonic(),
                cell.inputs[0],
                cell.out
            ),
            CellKind::Mux => format!(
                "tv_mux #(.W({w})) u{i} (.s(n{}), .a(n{}), .b(n{}), .y(n{}));",
                cell.inputs[0], cell.inputs[1], cell.inputs[2], cell.out
            ),
            CellKind::Shl { sh } => format!(
                "tv_shl #(.W({w}), .SH({sh})) u{i} (.a(n{}), .y(n{}));",
                cell.inputs[0], cell.out
            ),
            CellKind::Shr { sh, mode } => format!(
                "tv_shr #(.W({w}), .SH({sh}), .MODE({})) u{i} (.a(n{}), .y(n{}));",
                mode_code(*mode),
                cell.inputs[0],
                cell.out
            ),
            CellKind::And { mask } => format!(
                "tv_and #(.W({w}), .MASK({mask})) u{i} (.a(n{}), .y(n{}));",
                cell.inputs[0], cell.out
            ),
            CellKind::Clamp { lo, hi } => format!(
                "tv_clamp #(.W({w}), .LO({lo}), .HI({hi})) u{i} (.a(n{}), .y(n{}));",
                cell.inputs[0], cell.out
            ),
            CellKind::Rom { .. } => format!(
                "tv_rom_c{i} u{i} (.addr(n{}), .data(n{}));",
                cell.inputs[0], cell.out
            ),
            CellKind::NormShift { base, mode } => format!(
                "tv_normshift #(.W({w}), .BASE({base}), .MODE({})) u{i} (.a(n{}), .e(n{}), .y(n{}));",
                mode_code(*mode),
                cell.inputs[0],
                cell.inputs[1],
                cell.out
            ),
            CellKind::Reg => format!(
                "tv_reg #(.W({w})) u{i} (.clk(clk), .d(n{}), .q(n{}));",
                cell.inputs[0], cell.out
            ),
        };
        let _ = writeln!(s, "  {line}");
    }
    let _ = writeln!(s, "  assign y = n{};", d.output);
    let _ = writeln!(s, "endmodule");

    // One case-arm module per ROM instance.
    for (i, cell) in d.cells.iter().enumerate() {
        if let CellKind::Rom { entries } = &cell.kind {
            let _ = writeln!(s, "module tv_rom_c{i} (addr, data);");
            let _ = writeln!(s, "  input wire signed [126:0] addr;");
            let _ = writeln!(s, "  output reg signed [{}:0] data;", cell.width - 1);
            let _ = writeln!(s, "  always @* begin");
            let _ = writeln!(s, "    case (addr)");
            for (j, &v) in entries.iter().enumerate() {
                let _ = writeln!(s, "      {j}: data = {};", rom_literal(v, cell.width));
            }
            let last = *entries.last().expect("ROM has entries");
            let _ = writeln!(s, "      default: data = {};", rom_literal(last, cell.width));
            let _ = writeln!(s, "    endcase");
            let _ = writeln!(s, "  end");
            let _ = writeln!(s, "endmodule");
        }
    }

    // Behavioral reference primitives for the kinds this design uses.
    // The parser ignores everything from here on.
    let mut used: Vec<&'static str> = d
        .cells
        .iter()
        .map(|c| c.kind.mnemonic())
        .filter(|m| *m != "rom")
        .collect();
    used.sort_unstable();
    used.dedup();
    for m in used {
        let _ = writeln!(s, "{}", primitive_module(m));
    }
    s
}

/// Behavioral reference implementation for one primitive.
fn primitive_module(mnemonic: &str) -> &'static str {
    match mnemonic {
        "const" => "module tv_const #(parameter W = 1, parameter signed [126:0] V = 0) (y);\n  output wire signed [W-1:0] y;\n  assign y = V;\nendmodule",
        "add" => "module tv_add #(parameter W = 1) (a, b, y);\n  input wire signed [126:0] a, b;\n  output wire signed [W-1:0] y;\n  assign y = a + b;\nendmodule",
        "sub" => "module tv_sub #(parameter W = 1) (a, b, y);\n  input wire signed [126:0] a, b;\n  output wire signed [W-1:0] y;\n  assign y = a - b;\nendmodule",
        "mul" => "module tv_mul #(parameter W = 1) (a, b, y);\n  input wire signed [126:0] a, b;\n  output wire signed [W-1:0] y;\n  assign y = a * b;\nendmodule",
        "neg" => "module tv_neg #(parameter W = 1) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  assign y = -a;\nendmodule",
        "mux" => "module tv_mux #(parameter W = 1) (s, a, b, y);\n  input wire s;\n  input wire signed [126:0] a, b;\n  output wire signed [W-1:0] y;\n  assign y = s ? a : b;\nendmodule",
        "cmpge" => "module tv_cmpge #(parameter W = 1) (a, b, y);\n  input wire signed [126:0] a, b;\n  output wire y;\n  assign y = (a >= b);\nendmodule",
        "cmpeq" => "module tv_cmpeq #(parameter W = 1) (a, b, y);\n  input wire signed [126:0] a, b;\n  output wire y;\n  assign y = (a == b);\nendmodule",
        "isneg" => "module tv_isneg #(parameter W = 1) (a, y);\n  input wire signed [126:0] a;\n  output wire y;\n  assign y = (a < 0);\nendmodule",
        "not" => "module tv_not #(parameter W = 1) (a, y);\n  input wire signed [126:0] a;\n  output wire y;\n  assign y = (a == 0);\nendmodule",
        "shl" => "module tv_shl #(parameter W = 1, parameter SH = 0) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  assign y = a <<< SH;\nendmodule",
        "shr" => "module tv_shr #(parameter W = 1, parameter SH = 0, parameter MODE = 0) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  wire signed [126:0] fl = a >>> SH;\n  wire signed [126:0] rem = a - (fl <<< SH);\n  wire signed [126:0] half = (SH == 0) ? 127'sd0 : (127'sd1 <<< (SH - 1));\n  assign y = (SH == 0 || MODE == 0) ? fl\n           : (MODE == 1) ? ((a < 0) ? -(((-a) + half) >>> SH) : ((a + half) >>> SH))\n           : ((rem > half || (rem == half && fl[0])) ? fl + 127'sd1 : fl);\nendmodule",
        "and" => "module tv_and #(parameter W = 1, parameter signed [126:0] MASK = 0) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  assign y = a & MASK;\nendmodule",
        "clamp" => "module tv_clamp #(parameter W = 1, parameter signed [126:0] LO = 0, parameter signed [126:0] HI = 0) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  assign y = (a < LO) ? LO : (a > HI) ? HI : a;\nendmodule",
        "msb" => "module tv_msb #(parameter W = 7) (a, y);\n  input wire signed [126:0] a;\n  output wire signed [W-1:0] y;\n  reg [7:0] pos;\n  integer i;\n  always @* begin\n    pos = 8'd0;\n    for (i = 0; i < 126; i = i + 1) if (a[i]) pos = i[7:0];\n  end\n  assign y = (a <= 0) ? {W{1'b0}} : pos;\nendmodule",
        "normshift" => "module tv_normshift #(parameter W = 1, parameter signed [31:0] BASE = 0, parameter MODE = 0) (a, e, y);\n  input wire signed [126:0] a;\n  input wire signed [31:0] e;\n  output wire signed [W-1:0] y;\n  wire signed [31:0] amt = BASE + e;\n  wire signed [126:0] fl = a >>> amt;\n  wire signed [126:0] rem = a - (fl <<< amt);\n  wire signed [126:0] half = (amt <= 0) ? 127'sd0 : (127'sd1 <<< (amt - 1));\n  assign y = (amt < 0) ? (a <<< (-amt))\n           : (amt == 0 || MODE == 0) ? fl\n           : (MODE == 1) ? ((a < 0) ? -(((-a) + half) >>> amt) : ((a + half) >>> amt))\n           : ((rem > half || (rem == half && fl[0])) ? fl + 127'sd1 : fl);\nendmodule",
        "reg" => "module tv_reg #(parameter W = 1) (clk, d, q);\n  input wire clk;\n  input wire signed [W-1:0] d;\n  output reg signed [W-1:0] q;\n  always @(posedge clk) q <= d;\nendmodule",
        other => unreachable!("no primitive for '{other}'"),
    }
}

// ------------------------------------------------------------ parser

/// Splits `".a(n1), .b(n2)"` into top-level comma-separated items.
fn split_top(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut start) = (0usize, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(s[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    let tail = s[start..].trim();
    if !tail.is_empty() {
        out.push(tail);
    }
    out
}

/// Parses one `.key(value)` pair.
fn parse_pair(item: &str) -> Result<(&str, &str), String> {
    let item = item.trim();
    let rest = item
        .strip_prefix('.')
        .ok_or_else(|| format!("expected '.key(value)', got '{item}'"))?;
    let open = rest.find('(').ok_or_else(|| format!("missing '(' in '{item}'"))?;
    let close = rest.rfind(')').ok_or_else(|| format!("missing ')' in '{item}'"))?;
    Ok((rest[..open].trim(), rest[open + 1..close].trim()))
}

fn parse_net(s: &str) -> Result<usize, String> {
    s.strip_prefix('n')
        .and_then(|d| d.parse().ok())
        .ok_or_else(|| format!("expected net 'n<k>', got '{s}'"))
}

fn parse_i128(s: &str) -> Result<i128, String> {
    s.parse().map_err(|_| format!("bad integer '{s}'"))
}

/// Finds the span enclosed by the paren at `from` (which must be '('),
/// returning (inner, index after the closing paren).
fn paren_span(s: &str, from: usize) -> Result<(&str, usize), String> {
    debug_assert_eq!(&s[from..from + 1], "(");
    let mut depth = 0usize;
    for (i, c) in s[from..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((&s[from + 1..from + i], from + i + 1));
                }
            }
            _ => {}
        }
    }
    Err(format!("unbalanced parens in '{s}'"))
}

struct Instance<'a> {
    module: &'a str,
    index: usize,
    params: Vec<(&'a str, i128)>,
    ports: Vec<(&'a str, &'a str)>,
}

fn parse_instance(line: &str) -> Result<Instance<'_>, String> {
    let line = line.trim().trim_end_matches(';');
    let sp = line.find(char::is_whitespace).ok_or("truncated instance line")?;
    let module = &line[..sp];
    let mut rest = line[sp..].trim_start();
    let mut params = Vec::new();
    if let Some(stripped) = rest.strip_prefix('#') {
        let (inner, after) = paren_span(stripped, 0)?;
        for item in split_top(inner) {
            let (k, v) = parse_pair(item)?;
            params.push((k, parse_i128(v)?));
        }
        rest = stripped[after..].trim_start();
    }
    let usp = rest.find(char::is_whitespace).ok_or("missing instance name")?;
    let index: usize = rest[..usp]
        .strip_prefix('u')
        .and_then(|d| d.parse().ok())
        .ok_or_else(|| format!("expected instance 'u<i>', got '{}'", &rest[..usp]))?;
    let rest = rest[usp..].trim_start();
    if !rest.starts_with('(') {
        return Err(format!("missing port list in '{line}'"));
    }
    let (inner, _) = paren_span(rest, 0)?;
    let mut ports = Vec::new();
    for item in split_top(inner) {
        let (k, v) = parse_pair(item)?;
        ports.push((k, v));
    }
    Ok(Instance { module, index, params, ports })
}

impl<'a> Instance<'a> {
    fn param(&self, key: &str) -> Result<i128, String> {
        self.params
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| format!("u{}: missing parameter .{key}", self.index))
    }

    fn port(&self, key: &str) -> Result<&'a str, String> {
        self.ports
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| format!("u{}: missing port .{key}", self.index))
    }

    fn net(&self, key: &str) -> Result<usize, String> {
        parse_net(self.port(key)?)
    }
}

/// Parses a `<w>'sd<v>` (optionally negated) sized literal.
fn parse_rom_literal(s: &str) -> Result<i64, String> {
    let (neg, body) = match s.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, s),
    };
    let pos = body.find("'sd").ok_or_else(|| format!("bad ROM literal '{s}'"))?;
    let mag: i64 =
        body[pos + 3..].parse().map_err(|_| format!("bad ROM literal '{s}'"))?;
    Ok(if neg { -mag } else { mag })
}

/// Parses our own structural emission back into a [`Design`]. Narrow
/// by design: accepts exactly the shape [`emit`] produces.
pub fn parse(src: &str) -> Result<Design, String> {
    let mut name = None;
    let mut in_fmt = None;
    let mut out_fmt = None;
    let mut stages = None;
    let mut cell_count = None;
    let mut widths: Vec<(usize, u32)> = Vec::new();
    let mut output = None;
    let mut cells: Vec<Cell> = Vec::new();
    let mut roms: Vec<(usize, Vec<i64>)> = Vec::new();

    let mut lines = src.lines();
    // Header + main module.
    for line in lines.by_ref() {
        let t = line.trim();
        if let Some(v) = t.strip_prefix("// name: ") {
            name = Some(v.to_string());
        } else if let Some(v) = t.strip_prefix("// in: ") {
            in_fmt = Some(QFormat::parse(v).ok_or_else(|| format!("bad in format '{v}'"))?);
        } else if let Some(v) = t.strip_prefix("// out: ") {
            out_fmt = Some(QFormat::parse(v).ok_or_else(|| format!("bad out format '{v}'"))?);
        } else if let Some(v) = t.strip_prefix("// stages: ") {
            stages = Some(v.parse::<u32>().map_err(|_| format!("bad stage count '{v}'"))?);
        } else if let Some(v) = t.strip_prefix("// cells: ") {
            cell_count = Some(v.parse::<usize>().map_err(|_| format!("bad cell count '{v}'"))?);
        } else if let Some(v) = t.strip_prefix("wire signed [") {
            let close = v.find(":0] n").ok_or_else(|| format!("bad wire decl '{t}'"))?;
            let hi: u32 = v[..close].parse().map_err(|_| format!("bad wire decl '{t}'"))?;
            let net = parse_net(v[close + 4..].trim_end_matches(';'))?;
            widths.push((net, hi + 1));
        } else if let Some(v) = t.strip_prefix("wire n") {
            let net: usize = v
                .trim_end_matches(';')
                .parse()
                .map_err(|_| format!("bad wire decl '{t}'"))?;
            widths.push((net, 1));
        } else if let Some(v) = t.strip_prefix("assign y = ") {
            output = Some(parse_net(v.trim_end_matches(';'))?);
        } else if t.starts_with("tv_") {
            let inst = parse_instance(t)?;
            if inst.index != cells.len() {
                return Err(format!(
                    "instance u{} out of order (expected u{})",
                    inst.index,
                    cells.len()
                ));
            }
            let (kind, inputs) = decode_instance(&inst)?;
            let out_port = match inst.module {
                "tv_reg" => "q",
                m if m.starts_with("tv_rom_c") => "data",
                _ => "y",
            };
            let out = inst.net(out_port)?;
            let width = widths
                .iter()
                .find(|(n, _)| *n == out)
                .map(|(_, w)| *w)
                .ok_or_else(|| format!("u{}: no wire declared for n{out}", inst.index))?;
            cells.push(Cell { kind, inputs, out, width });
        } else if t == "endmodule" {
            break;
        }
    }
    // ROM case modules (behavioral primitives are ignored).
    let mut current: Option<(usize, Vec<i64>)> = None;
    for line in lines {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("module tv_rom_c") {
            let idx: usize = rest
                .split_whitespace()
                .next()
                .and_then(|w| w.parse().ok())
                .ok_or_else(|| format!("bad ROM module header '{t}'"))?;
            current = Some((idx, Vec::new()));
        } else if let Some((idx, mut entries)) = current.take() {
            if t == "endmodule" {
                roms.push((idx, entries));
            } else {
                if let Some(pos) = t.find(": data = ") {
                    let arm = &t[..pos];
                    if arm != "default" {
                        let j: usize =
                            arm.parse().map_err(|_| format!("bad ROM case arm '{t}'"))?;
                        if j != entries.len() {
                            return Err(format!("ROM c{idx} case arms out of order at {j}"));
                        }
                        let lit = t[pos + 9..].trim_end_matches(';');
                        entries.push(parse_rom_literal(lit)?);
                    }
                }
                current = Some((idx, entries));
            }
        }
    }
    for (idx, entries) in roms {
        let cell = cells
            .get_mut(idx)
            .ok_or_else(|| format!("ROM module c{idx} has no matching instance"))?;
        match &mut cell.kind {
            CellKind::Rom { entries: e } => *e = entries,
            other => {
                return Err(format!("ROM module c{idx} names a {} cell", other.mnemonic()))
            }
        }
    }
    for cell in &cells {
        if let CellKind::Rom { entries } = &cell.kind {
            if entries.is_empty() {
                return Err(format!("ROM feeding n{} has no case module", cell.out));
            }
        }
    }

    let d = Design {
        name: name.ok_or("missing '// name:' header")?,
        in_fmt: in_fmt.ok_or("missing '// in:' header")?,
        out_fmt: out_fmt.ok_or("missing '// out:' header")?,
        stages: stages.ok_or("missing '// stages:' header")?,
        output: output.ok_or("missing 'assign y' output binding")?,
        cells,
    };
    if let Some(want) = cell_count {
        if d.cells.len() != want {
            return Err(format!(
                "header declares {want} cells but {} instances were parsed",
                d.cells.len()
            ));
        }
    }
    d.validate()?;
    Ok(d)
}

/// Maps one parsed instance to its cell kind and input nets.
fn decode_instance(inst: &Instance<'_>) -> Result<(CellKind, Vec<usize>), String> {
    let two = |i: &Instance<'_>| -> Result<Vec<usize>, String> {
        Ok(vec![i.net("a")?, i.net("b")?])
    };
    let one = |i: &Instance<'_>| -> Result<Vec<usize>, String> { Ok(vec![i.net("a")?]) };
    Ok(match inst.module {
        "tv_const" => (CellKind::Const { value: inst.param("V")? }, vec![]),
        "tv_add" => (CellKind::Add, two(inst)?),
        "tv_sub" => (CellKind::Sub, two(inst)?),
        "tv_mul" => (CellKind::Mul, two(inst)?),
        "tv_neg" => (CellKind::Neg, one(inst)?),
        "tv_mux" => (
            CellKind::Mux,
            vec![inst.net("s")?, inst.net("a")?, inst.net("b")?],
        ),
        "tv_cmpge" => (CellKind::CmpGe, two(inst)?),
        "tv_cmpeq" => (CellKind::CmpEq, two(inst)?),
        "tv_isneg" => (CellKind::IsNeg, one(inst)?),
        "tv_not" => (CellKind::Not, one(inst)?),
        "tv_shl" => (CellKind::Shl { sh: inst.param("SH")? as u32 }, one(inst)?),
        "tv_shr" => (
            CellKind::Shr {
                sh: inst.param("SH")? as u32,
                mode: mode_parse(inst.param("MODE")?)?,
            },
            one(inst)?,
        ),
        "tv_and" => (CellKind::And { mask: inst.param("MASK")? }, one(inst)?),
        "tv_clamp" => (
            CellKind::Clamp { lo: inst.param("LO")?, hi: inst.param("HI")? },
            one(inst)?,
        ),
        "tv_msb" => (CellKind::Msb, one(inst)?),
        "tv_normshift" => (
            CellKind::NormShift {
                base: inst.param("BASE")? as i32,
                mode: mode_parse(inst.param("MODE")?)?,
            },
            vec![inst.net("a")?, inst.net("e")?],
        ),
        "tv_reg" => (CellKind::Reg, vec![inst.net("d")?]),
        m if m.starts_with("tv_rom_c") => {
            (CellKind::Rom { entries: Vec::new() }, vec![inst.net("addr")?])
        }
        other => return Err(format!("unknown primitive '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A design exercising every cell kind once.
    fn kitchen_sink() -> Design {
        let cells = vec![
            Cell { kind: CellKind::Const { value: -3 }, inputs: vec![], out: 1, width: 4 },
            Cell { kind: CellKind::Add, inputs: vec![0, 1], out: 2, width: 17 },
            Cell { kind: CellKind::Sub, inputs: vec![2, 1], out: 3, width: 18 },
            Cell { kind: CellKind::Mul, inputs: vec![3, 1], out: 4, width: 22 },
            Cell { kind: CellKind::Neg, inputs: vec![4], out: 5, width: 23 },
            Cell { kind: CellKind::IsNeg, inputs: vec![5], out: 6, width: 1 },
            Cell { kind: CellKind::Mux, inputs: vec![6, 5, 4], out: 7, width: 23 },
            Cell { kind: CellKind::CmpGe, inputs: vec![7, 1], out: 8, width: 1 },
            Cell { kind: CellKind::CmpEq, inputs: vec![7, 1], out: 9, width: 1 },
            Cell { kind: CellKind::Not, inputs: vec![9], out: 10, width: 1 },
            Cell { kind: CellKind::Shl { sh: 2 }, inputs: vec![7], out: 11, width: 25 },
            Cell {
                kind: CellKind::Shr { sh: 3, mode: Round::NearestEven },
                inputs: vec![11],
                out: 12,
                width: 22,
            },
            Cell { kind: CellKind::And { mask: 255 }, inputs: vec![12], out: 13, width: 8 },
            Cell {
                kind: CellKind::Clamp { lo: -100, hi: 100 },
                inputs: vec![13],
                out: 14,
                width: 8,
            },
            Cell {
                kind: CellKind::Rom { entries: vec![0, -7, 42] },
                inputs: vec![13],
                out: 15,
                width: 16,
            },
            Cell { kind: CellKind::Msb, inputs: vec![15], out: 16, width: 7 },
            Cell {
                kind: CellKind::NormShift { base: -29, mode: Round::NearestAway },
                inputs: vec![15, 16],
                out: 17,
                width: 32,
            },
            Cell { kind: CellKind::Reg, inputs: vec![17], out: 18, width: 32 },
            Cell {
                kind: CellKind::Clamp { lo: -32768, hi: 32767 },
                inputs: vec![18],
                out: 19,
                width: 16,
            },
        ];
        Design {
            name: "kitchen-sink".into(),
            in_fmt: QFormat::new(3, 12),
            out_fmt: QFormat::new(0, 15),
            stages: 2,
            output: 19,
            cells,
        }
    }

    #[test]
    fn kitchen_sink_round_trips_exactly() {
        let d = kitchen_sink();
        assert!(d.validate().is_ok());
        let v = emit(&d);
        let back = parse(&v).expect("own emission parses");
        assert_eq!(back, d);
    }

    #[test]
    fn emission_is_deterministic_and_structural() {
        let d = kitchen_sink();
        let v = emit(&d);
        assert_eq!(v, emit(&d));
        assert!(v.starts_with("// tanh-vlsi rtl netlist\n"));
        assert!(v.contains("module tanh_rtl (clk, x, y);"));
        assert!(v.contains("tv_rom_c14 u14 (.addr(n13), .data(n15));"));
        assert!(v.contains("module tv_rom_c14 (addr, data);"));
        assert!(v.contains("-16'sd7"));
        assert!(v.contains("module tv_reg"));
    }

    #[test]
    fn tampered_emissions_are_rejected() {
        let d = kitchen_sink();
        let v = emit(&d);
        // Instance order is part of the contract.
        let swapped = v.replacen("u1 ", "u2 ", 1);
        assert!(parse(&swapped).is_err());
        // A forward reference violates topological order.
        let fwd = v.replace("(.a(n0), .b(n1), .y(n2))", "(.a(n5), .b(n1), .y(n2))");
        assert!(parse(&fwd).is_err());
    }

    #[test]
    fn rom_literals_round_trip_signs() {
        assert_eq!(parse_rom_literal("16'sd42").unwrap(), 42);
        assert_eq!(parse_rom_literal("-16'sd7").unwrap(), -7);
        assert!(parse_rom_literal("junk").is_err());
    }
}
