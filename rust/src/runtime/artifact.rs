//! Artifact directory: manifest parsing and lookup.

use std::path::{Path, PathBuf};

use crate::rt_err;
use crate::util::error::{Context, RtResult as Result};

use crate::util::json::{self, Json};

/// Shape + dtype of one graph input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Dtype name as emitted by jax ("float32" / "int32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name (manifest key), e.g. `tanh_pwl_1024`.
    pub name: String,
    /// HLO text file (relative to the artifact dir).
    pub file: PathBuf,
    /// Input tensor specs in call order.
    pub inputs: Vec<TensorSpec>,
}

/// A parsed `artifacts/` directory.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    root: PathBuf,
    entries: Vec<ArtifactMeta>,
}

impl ArtifactDir {
    /// Opens a directory by reading its `manifest.json` (produced by
    /// `python -m compile.aot`).
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactDir> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let doc = json::parse(&text).map_err(|e| rt_err!("manifest parse: {e}"))?;
        let Json::Obj(map) = doc else {
            return Err(rt_err!("manifest must be an object"));
        };
        let mut entries = Vec::new();
        for (name, entry) in map {
            let file = entry
                .get("file")
                .and_then(|f| f.str())
                .ok_or_else(|| rt_err!("{name}: missing file"))?;
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| rt_err!("{name}: missing inputs"))?
                .iter()
                .map(|spec| -> Result<TensorSpec> {
                    let shape = spec
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| rt_err!("{name}: missing shape"))?
                        .iter()
                        .map(|d| d.num().unwrap_or(0.0) as usize)
                        .collect();
                    let dtype = spec
                        .get("dtype")
                        .and_then(|d| d.str())
                        .unwrap_or("float32")
                        .to_string();
                    Ok(TensorSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactMeta { name, file: PathBuf::from(file), inputs });
        }
        Ok(ArtifactDir { root, entries })
    }

    /// The default location relative to the repo root, overridable with
    /// `TANH_VLSI_ARTIFACTS`.
    pub fn default_path() -> PathBuf {
        std::env::var("TANH_VLSI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    /// Looks an entry up by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.root.join(&meta.file)
    }

    /// Root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"tanh_pwl_1024": {"file": "tanh_pwl_1024.hlo.txt",
                 "inputs": [{"shape": [1024], "dtype": "float32"}]},
                "lstm_cell_ref": {"file": "lstm_cell_ref.hlo.txt",
                 "inputs": [{"shape": [32, 4], "dtype": "float32"},
                            {"shape": [32, 64], "dtype": "float32"},
                            {"shape": [32, 64], "dtype": "float32"}]}}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("tanh_vlsi_artifact_test");
        write_fixture(&dir);
        let a = ArtifactDir::open(&dir).unwrap();
        assert_eq!(a.entries().len(), 2);
        let meta = a.get("tanh_pwl_1024").unwrap();
        assert_eq!(meta.inputs.len(), 1);
        assert_eq!(meta.inputs[0].shape, vec![1024]);
        assert_eq!(meta.inputs[0].elements(), 1024);
        let lstm = a.get("lstm_cell_ref").unwrap();
        assert_eq!(lstm.inputs.len(), 3);
        assert_eq!(lstm.inputs[1].shape, vec![32, 64]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_errors() {
        let err = ArtifactDir::open("/nonexistent/nowhere").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }
}
