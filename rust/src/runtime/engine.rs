//! The PJRT engine: compile-once, execute-many.

use std::collections::HashMap;
use std::sync::Mutex;

// Swap this alias for `use xla;` when the real PJRT bindings are linked.
use super::xla_shim as xla;
use crate::rt_err;
use crate::util::error::{Context, RtResult as Result};

use super::artifact::{ArtifactDir, ArtifactMeta};

/// A tensor crossing the runtime boundary (we only need f32/i32 — the
/// two dtypes the paper's fixed-point story involves).
#[derive(Clone, Debug)]
pub enum TensorValue {
    /// float32 data (row-major).
    F32(Vec<f32>),
    /// int32 raw fixed-point words.
    I32(Vec<i32>),
}

impl TensorValue {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32(v) => v.len(),
            TensorValue::I32(v) => v.len(),
        }
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Extracts f32 data.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorValue::F32(v) => Ok(v),
            TensorValue::I32(_) => Err(rt_err!("tensor is i32, expected f32")),
        }
    }

    /// Extracts i32 data.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorValue::I32(v) => Ok(v),
            TensorValue::F32(_) => Err(rt_err!("tensor is f32, expected i32")),
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        let lit = match self {
            TensorValue::F32(v) => xla::Literal::vec1(v),
            TensorValue::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<TensorValue> {
        match lit.ty()? {
            xla::ElementType::F32 => Ok(TensorValue::F32(lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(TensorValue::I32(lit.to_vec::<i32>()?)),
            other => Err(rt_err!("unsupported output dtype {other:?}")),
        }
    }
}

/// One compiled graph, ready to execute.
pub struct LoadedGraph {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedGraph {
    /// Input metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Executes with the given inputs (shapes from the manifest) and
    /// returns the flattened tuple outputs.
    pub fn execute(&self, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(rt_err!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, spec) in inputs.iter().zip(&self.meta.inputs) {
            if value.len() != spec.elements() {
                return Err(rt_err!(
                    "{}: input expects {} elements, got {}",
                    self.meta.name,
                    spec.elements(),
                    value.len()
                ));
            }
            literals.push(value.to_literal(&spec.shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Graphs are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        parts.iter().map(TensorValue::from_literal).collect()
    }
}

/// The engine: a PJRT CPU client plus a lazily-populated executable
/// cache over an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: ArtifactDir,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedGraph>>>,
}

impl Engine {
    /// Creates a CPU-PJRT engine over an artifact directory.
    pub fn cpu(artifacts: ArtifactDir) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, artifacts, cache: Mutex::new(HashMap::new()) })
    }

    /// The artifact directory.
    pub fn artifacts(&self) -> &ArtifactDir {
        &self.artifacts
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Loads (compiling if necessary) a graph by manifest name. The
    /// compiled executable is cached — compile-once, execute-many.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let meta = self
            .artifacts
            .get(name)
            .ok_or_else(|| rt_err!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.artifacts.path_of(&meta);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let graph = std::sync::Arc::new(LoadedGraph { meta, exe });
        self.cache.lock().unwrap().insert(name.to_string(), graph.clone());
        Ok(graph)
    }

    /// Convenience: run a single-input single-output f32 graph.
    pub fn run_f32(&self, name: &str, input: Vec<f32>) -> Result<Vec<f32>> {
        let graph = self.load(name)?;
        let out = graph.execute(&[TensorValue::F32(input)])?;
        Ok(out
            .into_iter()
            .next()
            .ok_or_else(|| rt_err!("{name}: empty output tuple"))?
            .as_f32()?
            .to_vec())
    }
}
