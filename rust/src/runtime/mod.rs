//! PJRT runtime: loads the JAX/Pallas AOT artifacts (`artifacts/
//! *.hlo.txt`) and executes them from the rust hot path.
//!
//! Python never runs at serving time — the rust binary consumes only
//! the HLO *text* artifacts (`HloModuleProto::from_text_file`; text
//! rather than serialized protos because the image's xla_extension
//! 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos, see
//! /opt/xla-example/README.md), compiles them once on the PJRT CPU
//! client, and keeps the loaded executables hot.
//!
//! In builds without the `xla` bindings (the offline crate set ships
//! none), [`xla_shim`] stands in: same API surface, every PJRT entry
//! point reports "unavailable". Serving surfaces this cleanly through
//! [`crate::backend::PjrtBackend`], which wraps the engine in its own
//! submission thread and reports
//! [`Availability::Unavailable`](crate::backend::Availability) instead
//! of panicking; the golden/hw backends carry serving through the
//! compiled integer kernels and the cycle-accurate datapaths instead.
//! (The old `EngineServer` wrapper was folded into `PjrtBackend` when
//! the execution layer unified on
//! [`crate::backend::EvalBackend`].)

mod artifact;
mod engine;
pub mod xla_shim;

pub use artifact::{ArtifactDir, ArtifactMeta, TensorSpec};
pub use engine::{Engine, LoadedGraph, TensorValue};
