//! Engine thread: the PJRT client and executables are not `Send`
//! (the `xla` crate wraps raw pointers / `Rc` internally), so a single
//! dedicated thread owns them and serves execute jobs over a channel.
//! This mirrors how accelerator command queues actually work: one
//! submission context, many logical clients.

use std::sync::mpsc;
use std::sync::Mutex;

use crate::rt_err;
use crate::util::error::RtResult as Result;

use super::artifact::ArtifactDir;
use super::engine::{Engine, TensorValue};

enum Job {
    Execute {
        name: String,
        inputs: Vec<TensorValue>,
        reply: mpsc::Sender<Result<Vec<TensorValue>, String>>,
    },
    Preload {
        names: Vec<String>,
        reply: mpsc::Sender<Result<(), String>>,
    },
}

/// A `Send + Sync` handle to the engine thread.
pub struct EngineServer {
    tx: Mutex<mpsc::Sender<Job>>,
    platform: String,
}

impl EngineServer {
    /// Spawns the engine thread over an artifact directory.
    pub fn spawn(artifacts: ArtifactDir) -> Result<EngineServer> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<String, String>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::cpu(artifacts) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(e.platform()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Execute { name, inputs, reply } => {
                            let result = engine
                                .load(&name)
                                .and_then(|g| g.execute(&inputs))
                                .map_err(|e| e.to_string());
                            let _ = reply.send(result);
                        }
                        Job::Preload { names, reply } => {
                            let mut result = Ok(());
                            for name in names {
                                if let Err(e) = engine.load(&name) {
                                    result = Err(e.to_string());
                                    break;
                                }
                            }
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .expect("spawning engine thread");
        let platform = init_rx
            .recv()
            .map_err(|_| rt_err!("engine thread died during init"))?
            .map_err(|e| rt_err!("engine init: {e}"))?;
        Ok(EngineServer { tx: Mutex::new(tx), platform })
    }

    /// Spawns over the default artifact path.
    pub fn spawn_default() -> Result<EngineServer> {
        EngineServer::spawn(ArtifactDir::open(ArtifactDir::default_path())?)
    }

    /// PJRT platform name.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Compiles a set of graphs ahead of the hot path.
    pub fn preload(&self, names: &[&str]) -> Result<(), String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Preload { names: iter_strings(names), reply })
            .map_err(|_| "engine thread gone".to_string())?;
        rx.recv().map_err(|_| "engine thread gone".to_string())?
    }

    /// Executes a graph by artifact name (blocking).
    pub fn execute(
        &self,
        name: &str,
        inputs: Vec<TensorValue>,
    ) -> Result<Vec<TensorValue>, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Execute { name: name.to_string(), inputs, reply })
            .map_err(|_| "engine thread gone".to_string())?;
        rx.recv().map_err(|_| "engine thread gone".to_string())?
    }

    /// Convenience: single f32-in / f32-out graph.
    pub fn run_f32(&self, name: &str, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let out = self.execute(name, vec![TensorValue::F32(input)])?;
        out.into_iter()
            .next()
            .ok_or_else(|| "empty tuple".to_string())?
            .as_f32()
            .map(|v| v.to_vec())
            .map_err(|e| e.to_string())
    }
}

fn iter_strings(names: &[&str]) -> Vec<String> {
    names.iter().map(|s| s.to_string()).collect()
}
