//! Loadable-runtime shim for the `xla` PJRT bindings.
//!
//! The offline image this crate builds in does not ship the `xla`
//! crate (nor a crates.io registry to fetch it from), so the engine is
//! written against this shim instead: the exact API slice
//! [`super::engine`] consumes, with every entry point that would touch
//! PJRT returning a descriptive error. When the real bindings are
//! available, swap `use super::xla_shim as xla;` in `engine.rs` for
//! `use xla;` — no other code changes are needed, which is the point of
//! keeping the shim's signatures bit-compatible.
//!
//! Serving does not regress from this: [`crate::backend::PjrtBackend`]
//! reports `Unavailable` (so `--backend pjrt` fails fast with a
//! `backend_unavailable` error instead of panicking), while the golden
//! and hw backends ([`crate::backend::GoldenBackend`],
//! [`crate::backend::HwBackend`]) carry serving through the compiled
//! integer kernels and the cycle-accurate datapaths.

use std::path::Path;

use crate::util::error::RtError;

/// Error/Result aliases matching the `?`-conversion the engine relies on.
pub type Error = RtError;
/// Shim-local result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    RtError::msg(format!(
        "{what}: PJRT runtime unavailable in this build (xla bindings not linked; \
         see runtime::xla_shim)"
    ))
}

/// Element dtypes the engine distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// float32.
    F32,
    /// signed int32 (raw fixed-point words).
    S32,
    /// Other dtypes the manifest could declare; never constructed here.
    Other,
}

/// A host-side tensor literal (stub: carries no data).
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    /// Builds a rank-1 literal from a slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshapes to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// The element dtype.
    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable("Literal::ty"))
    }

    /// Copies the data out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructures a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// A device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copies the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Executes with the given argument literals; returns per-device,
    /// per-output buffers (`[replica][output]`).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Creates a CPU-backed client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Compiles an XLA computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parses an HLO text file (the AOT artifact format).
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wraps a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_ops_fail_gracefully() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
