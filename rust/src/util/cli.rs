//! Declarative CLI parsing (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with generated `--help` text. Only what the `tanh-vlsi`
//! binary and the examples need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Long name without the leading `--`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// If true the option is a boolean flag (takes no value).
    pub is_flag: bool,
    /// Default value rendered in help (flags ignore this).
    pub default: Option<&'static str>,
}

/// A parsed command line: option values + positionals.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Parsed {
    /// Returns the raw string value of `--name` if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Returns the value of `--name` or the given default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// True if the boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parses `--name` as `T`, with a default.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("invalid value '{v}' for --{name}")),
        }
    }
}

/// A CLI command: name, help, options, and positional descriptor.
#[derive(Debug)]
pub struct Command {
    /// Subcommand name (empty for the root).
    pub name: &'static str,
    /// One-line description shown in help.
    pub about: &'static str,
    /// Option specifications.
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// Builds a command spec.
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new() }
    }

    /// Adds a value option.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default });
        self
    }

    /// Adds a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    /// Renders `--help` output.
    pub fn help(&self, prog: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} {}\n{}\n", prog, self.name, self.about);
        let _ = writeln!(out, "OPTIONS:");
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(out, "{left:34} {}{default}", o.help);
        }
        out
    }

    /// Parses argv (after the subcommand token). Unknown options error.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut parsed = Parsed::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name} (see --help)"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    parsed.flags.push(name.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{name} needs a value"))?
                        }
                    };
                    parsed.values.insert(name.to_string(), val);
                }
            } else {
                parsed.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }
}

/// A multi-command CLI application.
pub struct App {
    /// Program name for help output.
    pub prog: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Subcommands.
    pub commands: Vec<Command>,
}

impl App {
    /// Renders top-level help.
    pub fn help(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}\n", self.prog, self.about);
        let _ = writeln!(out, "USAGE: {} <command> [options]\n\nCOMMANDS:", self.prog);
        for c in &self.commands {
            let _ = writeln!(out, "  {:22} {}", c.name, c.about);
        }
        let _ = writeln!(out, "\nRun '{} <command> --help' for command options.", self.prog);
        out
    }

    /// Dispatches argv: returns the matched command + parsed options, or
    /// a help/error string to print.
    pub fn dispatch<'a>(&'a self, argv: &[String]) -> Result<(&'a Command, Parsed), String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.help());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.help()))?;
        let rest = &argv[1..];
        if rest.iter().any(|a| a == "--help" || a == "-h") {
            return Err(cmd.help(self.prog));
        }
        let parsed = cmd.parse(rest)?;
        Ok((cmd, parsed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            prog: "tanh-vlsi",
            about: "test",
            commands: vec![
                Command::new("eval", "evaluate")
                    .opt("method", "method id", Some("pwl"))
                    .opt("x", "input", None)
                    .flag("verbose", "more output"),
                Command::new("table1", "table 1"),
            ],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = app();
        let (cmd, p) = a.dispatch(&argv(&["eval", "--method", "taylor", "--verbose", "pos1", "--x=0.5"])).unwrap();
        assert_eq!(cmd.name, "eval");
        assert_eq!(p.get("method"), Some("taylor"));
        assert_eq!(p.get("x"), Some("0.5"));
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        let a = app();
        assert!(a.dispatch(&argv(&["eval", "--nope"])).is_err());
    }

    #[test]
    fn unknown_command_shows_help() {
        let a = app();
        let err = a.dispatch(&argv(&["zzz"])).unwrap_err();
        assert!(err.contains("unknown command"));
        assert!(err.contains("COMMANDS"));
    }

    #[test]
    fn help_flag_returns_help_text() {
        let a = app();
        let err = a.dispatch(&argv(&["eval", "--help"])).unwrap_err();
        assert!(err.contains("--method"));
    }

    #[test]
    fn missing_value_errors() {
        let a = app();
        assert!(a.dispatch(&argv(&["eval", "--method"])).is_err());
    }

    #[test]
    fn parse_or_types() {
        let a = app();
        let (_, p) = a.dispatch(&argv(&["eval", "--x", "1.25"])).unwrap();
        let x: f64 = p.parse_or("x", 0.0).unwrap();
        assert_eq!(x, 1.25);
        let bad: Result<f64, _> = a
            .dispatch(&argv(&["eval", "--x", "abc"]))
            .and_then(|(_, p)| p.parse_or("x", 0.0));
        assert!(bad.is_err());
    }
}
