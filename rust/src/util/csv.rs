//! CSV writer for figure data series (Fig 2 sweeps etc.).

use std::io::{self, Write};
use std::path::Path;

/// A CSV document with a fixed header.
#[derive(Clone, Debug)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// Creates a CSV with the given column names.
    pub fn new(header: &[&str]) -> Csv {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Appends a row of raw cells (quoted as needed on render).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Renders the document to a string (RFC-4180 quoting).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&quote_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&quote_row(row));
            out.push('\n');
        }
        out
    }

    /// Writes the document to a file, creating parent directories.
    pub fn write_file(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

fn quote_cell(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

fn quote_row(cells: &[String]) -> String {
    cells.iter().map(|c| quote_cell(c)).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_quotes() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into(), "x,y".into()]);
        c.row(vec!["2".into(), "he said \"hi\"".into()]);
        let s = c.render();
        assert_eq!(s.lines().next().unwrap(), "a,b");
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("tanh_vlsi_csv_test");
        let path = dir.join("sub/out.csv");
        let mut c = Csv::new(&["v"]);
        c.row(vec!["42".into()]);
        c.write_file(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "v\n42\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
