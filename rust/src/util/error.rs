//! Minimal error type for the runtime layers (anyhow is not in the
//! offline crate set): a message-carrying error, a `Result` alias and a
//! `Context` extension trait mirroring the `anyhow::Context` surface the
//! runtime modules use.

use std::fmt;

/// A string-backed error with optional context frames.
#[derive(Clone, Debug)]
pub struct RtError(String);

impl RtError {
    /// Builds an error from a message.
    pub fn msg(m: impl Into<String>) -> RtError {
        RtError(m.into())
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

impl From<String> for RtError {
    fn from(s: String) -> RtError {
        RtError(s)
    }
}

impl From<&str> for RtError {
    fn from(s: &str) -> RtError {
        RtError(s.to_string())
    }
}

impl From<std::io::Error> for RtError {
    fn from(e: std::io::Error) -> RtError {
        RtError(e.to_string())
    }
}

/// Result alias used by the runtime / coordinator load paths. The
/// defaulted error parameter mirrors `anyhow::Result` so call sites can
/// still write `Result<T, String>` where they need a plain error type.
pub type RtResult<T, E = RtError> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension: wrap any displayable error with a
/// human-readable frame (`"reading manifest.json: <cause>"`).
pub trait Context<T> {
    /// Adds a static context message.
    fn context(self, msg: &str) -> RtResult<T>;
    /// Adds a lazily-built context message.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> RtResult<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> RtResult<T> {
        self.map_err(|e| RtError(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> RtResult<T> {
        self.map_err(|e| RtError(format!("{}: {e}", f())))
    }
}

/// `anyhow!`-style formatting constructor for [`RtError`].
#[macro_export]
macro_rules! rt_err {
    ($($arg:tt)*) => {
        $crate::util::error::RtError::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_wraps_cause() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "no such file",
        ));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().contains("reading manifest"));
        assert!(e.to_string().contains("no such file"));
    }

    #[test]
    fn macro_formats() {
        let e = rt_err!("artifact '{}' missing", "tanh_pwl_1024");
        assert_eq!(e.to_string(), "artifact 'tanh_pwl_1024' missing");
    }

    #[test]
    fn with_context_is_lazy_formatted() {
        let r: RtResult<()> = Err(RtError::msg("cause"));
        let e = r.with_context(|| format!("frame {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "frame 7: cause");
    }
}
