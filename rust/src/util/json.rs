//! Minimal JSON value model + serializer (serde is not vendored).
//!
//! Only what the metrics endpoints and report writers need: building
//! objects/arrays programmatically and writing compact or pretty text.
//! A small parser is included for the coordinator's request protocol and
//! round-trip tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Builds an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builds an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String convenience.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Number convenience.
    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    /// Integer convenience (exact for |v| < 2^53).
    pub fn i(v: i64) -> Json {
        Json::Num(v as f64)
    }

    /// Gets an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Gets a numeric field.
    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Gets a string field.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Gets an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                // JSON has no NaN/Infinity: `write!("{v}")` on a
                // non-finite f64 would emit `NaN`/`inf` and break every
                // conforming parser (including ours). Emit `null`, the
                // standard lossy encoding.
                if !v.is_finite() {
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parses a JSON document. Accepts the full JSON grammar minus exotic
/// number forms; good enough for the request protocol + tests.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        // `parse::<f64>` accepts overflowing literals like `1e999` by
        // rounding them to infinity (and would accept `NaN`/`inf`
        // spellings if the dispatcher let them through). Non-finite
        // values are not JSON; reject them instead of letting them
        // leak into request handling.
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|v| v.is_finite())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.pos..];
                    let s_rest = std::str::from_utf8(rest).map_err(|_| "invalid utf8")?;
                    let c = s_rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']' got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected ',' or '}}' got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj(vec![
            ("name", Json::s("pwl")),
            ("step", Json::n(0.015625)),
            ("terms", Json::i(3)),
            ("tags", Json::arr(vec![Json::s("a"), Json::s("b")])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.to_string_compact();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x\ny"}],"c":-1.5e-3}"#).unwrap();
        assert_eq!(v.get("c").unwrap().num().unwrap(), -1.5e-3);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse(r#"{"a"1}"#).is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::s("a\"b\\c\nd\u{1}");
        let text = v.to_string_compact();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::i(42).to_string_compact(), "42");
        assert_eq!(Json::n(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // Regression: these used to render as `NaN` / `inf` / `-inf`,
        // which no JSON parser accepts.
        assert_eq!(Json::n(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::n(f64::INFINITY).to_string_compact(), "null");
        assert_eq!(Json::n(f64::NEG_INFINITY).to_string_compact(), "null");
        // Round trip: a document carrying a non-finite number comes
        // back as the same document with Null in its place.
        let v = Json::obj(vec![("a", Json::n(f64::NAN)), ("b", Json::n(1.5))]);
        let back = parse(&v.to_string_compact()).unwrap();
        assert_eq!(back.get("a"), Some(&Json::Null));
        assert_eq!(back.get("b").unwrap().num(), Some(1.5));
        // Pretty output is valid too.
        assert!(parse(&v.to_string_pretty()).is_ok());
    }

    #[test]
    fn parser_rejects_non_finite_number_tokens() {
        // Bare NaN/inf spellings are not JSON values.
        assert!(parse("NaN").is_err());
        assert!(parse("inf").is_err());
        assert!(parse("-inf").is_err());
        assert!(parse("Infinity").is_err());
        assert!(parse("[NaN]").is_err());
        assert!(parse(r#"{"values":[NaN]}"#).is_err());
        // Overflowing literals round to infinity inside f64::parse;
        // they must be rejected, not smuggled in as Num(inf).
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
        assert!(parse(r#"[1.0, 1e999]"#).is_err());
        // Ordinary large-but-finite literals still parse.
        assert_eq!(parse("1e300").unwrap().num(), Some(1e300));
    }
}
