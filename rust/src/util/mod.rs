//! Small infrastructure substrates the offline image forces us to own.
//!
//! The vendored crate set contains neither `clap`, `serde`, `rand`,
//! `proptest` nor `criterion`, so this module provides minimal,
//! well-tested replacements:
//!
//! - [`cli`] — declarative flag/subcommand parser for the `tanh-vlsi` binary,
//! - [`prng`] — splitmix64/xoshiro256** deterministic PRNG,
//! - [`proptest`] — seeded property-test runner with shrinking,
//! - [`json`] — minimal JSON value model + writer (reports, metrics),
//! - [`csv`] — CSV writer for figure series,
//! - [`table`] — aligned text tables for paper-style output,
//! - [`error`] — anyhow-style message error for the runtime load paths.

pub mod cli;
pub mod csv;
pub mod error;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod table;

pub use prng::Prng;
