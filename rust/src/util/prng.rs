//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! `rand` is not in the offline crate set; this is the standard public
//! domain xoshiro256** construction (Blackman & Vigna), plenty for test
//! input generation and workload synthesis (never used for crypto).

/// xoshiro256** generator with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Builds a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Prng {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn u64_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform i64 in [lo, hi] inclusive.
    #[inline]
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.u64_below(span) as i64)
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.u64_below(n as u64) as usize
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Picks a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Standard normal via Box-Muller (used by workload generators).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Prng::new(7);
        for _ in 0..10_000 {
            let v = g.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn u64_below_in_range_and_covers() {
        let mut g = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.u64_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn i64_in_inclusive_bounds() {
        let mut g = Prng::new(11);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..20_000 {
            let v = g.i64_in(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn normal_mean_and_var_roughly_standard() {
        let mut g = Prng::new(13);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let v = g.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
