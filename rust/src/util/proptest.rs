//! Minimal property-test runner (proptest is not in the offline set).
//!
//! A property is a closure `FnMut(&mut Prng) -> Result<(), String>`; the
//! runner executes it `cases` times with a fixed base seed (so failures
//! are reproducible) and, on failure, retries the failing seed reporting
//! the case index — enough for the invariant-style properties this crate
//! uses. Seeds can be overridden with `TANH_VLSI_PROP_SEED` to replay.

pub use super::prng::Prng;

/// Runs `cases` random cases of `prop`; panics with diagnostics on the
/// first failure.
pub fn prop_check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let base_seed = std::env::var("TANH_VLSI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        // Derive a per-case seed so a failure report pinpoints one case.
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Prng::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (seed {seed}, set TANH_VLSI_PROP_SEED={base_seed} to replay): {msg}"
            );
        }
    }
}

/// Like [`prop_check`] but the property also receives the case index —
/// useful for sweeping structured inputs deterministically.
pub fn prop_check_indexed<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(u32, &mut Prng) -> Result<(), String>,
{
    let base_seed = std::env::var("TANH_VLSI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Prng::new(seed);
        if let Err(msg) = prop(case, &mut g) {
            panic!("property '{name}' failed at case {case}/{cases} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("trivially true", 100, |_g| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        prop_check("always fails", 10, |_g| Err("nope".into()));
    }

    #[test]
    fn indexed_variant_sees_all_indices() {
        let mut seen = vec![false; 10];
        prop_check_indexed("indices", 10, |i, _g| {
            seen[i as usize] = true;
            Ok(())
        });
        assert!(seen.iter().all(|&b| b));
    }
}
