//! Aligned text-table rendering for paper-style report output.

/// A simple text table with a header row and aligned columns.
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// Creates a table with the given column headers.
    pub fn new(header: &[&str]) -> TextTable {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Appends a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if there are no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Renders with unicode box separators, e.g.
    /// ```text
    /// method | step  | max_err
    /// -------+-------+--------
    /// PWL    | 1/64  | 4.7e-5
    /// ```
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(
            &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Renders as GitHub-flavored markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

/// Formats a float in the paper's scientific style, e.g. `1.24e-5`.
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    format!("{v:.2e}")
}

/// Formats a step size as the paper writes it (`1/64`) when it is an
/// exact reciprocal power of two, falling back to decimal.
pub fn step_str(step: f64) -> String {
    if step > 0.0 {
        let inv = 1.0 / step;
        if inv.fract() == 0.0 && inv >= 1.0 {
            return format!("1/{}", inv as u64);
        }
    }
    format!("{step}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["method", "err"]);
        t.row(vec!["PWL".into(), "4.65e-5".into()]);
        t.row(vec!["Lambert".into(), "4.87e-5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("method"));
        assert!(lines[2].starts_with("PWL "));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_mismatch_panics() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn step_formatting() {
        assert_eq!(step_str(1.0 / 64.0), "1/64");
        assert_eq!(step_str(0.3), "0.3");
        assert_eq!(sci(1.24e-5), "1.24e-5");
    }
}
