//! Cross-backend conformance suite: the hw backend must be an
//! interchangeable, bit-exact realization of the golden kernels for
//! *any* servable design point — not just the six Table I rows — and
//! its measured cycle accounting must obey the streaming contract
//! (nonzero, monotone in batch size, steady-state ≤ per-batch
//! re-fill). A regression band pins the analytic §IV cost model
//! against the measured hw cycles so model drift or lowering
//! regressions fail loudly.

use std::sync::{Arc, Mutex};
use std::collections::HashMap;

use tanh_vlsi::approx::{IoSpec, MethodId, MethodSpec};
use tanh_vlsi::backend::{
    analytic_cost, Availability, BackendError, CostProbe, EvalBackend, EvalStats, GoldenBackend,
    HwBackend,
};
use tanh_vlsi::bench::scenario::build_trace;
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig};
use tanh_vlsi::error::InputGrid;
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::hw::{pipeline_for, Pipeline};
use tanh_vlsi::util::prng::Prng;

/// Seeded non-Table-I design points: random method × parameter ×
/// output format × domain combinations (plus the S2.13 input variant
/// for the polynomial family, whose lowering supports it). The
/// full-grid cross-check below is exhaustive per spec.
fn random_specs(n: usize, seed: u64) -> Vec<MethodSpec> {
    let mut g = Prng::new(seed);
    let table1 = MethodSpec::table1_all();
    let mut specs: Vec<MethodSpec> = Vec::new();
    while specs.len() < n {
        let id = *g.choose(&MethodId::all());
        let input = match id {
            // The Fig 3 index extraction is a bit-field select, so the
            // polynomial family lowers for any input format; keep the
            // rational methods on the Table I input.
            MethodId::Pwl | MethodId::CatmullRom if g.bool(0.5) => QFormat::S2_13,
            _ => QFormat::S3_12,
        };
        let output = if g.bool(0.5) { QFormat::S_15 } else { QFormat::S_7 };
        let io = IoSpec { input, output };
        let param = match id {
            MethodId::Lambert => g.i64_in(2, 10) as f64,
            _ => (2f64).powi(-g.i64_in(3, 6) as i32),
        };
        let domain = if g.bool(0.5) { 6.0 } else { 4.0 };
        if let Ok(spec) = MethodSpec::with_param(id, param, io, domain) {
            if !specs.contains(&spec) && !table1.contains(&spec) {
                specs.push(spec);
            }
        }
    }
    specs
}

#[test]
fn hw_matches_golden_bit_exact_on_full_grids() {
    // Every Table I spec plus ≥4 seeded random non-Table-I specs:
    // hw == golden raw-for-raw over the spec's FULL domain grid.
    let hw = HwBackend::new();
    let golden = GoldenBackend::new();
    let mut specs = MethodSpec::table1_all();
    specs.extend(random_specs(4, 0xC0FFEE));
    assert!(specs.len() >= 10);
    for spec in specs {
        hw.ensure(&spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        golden.ensure(&spec).unwrap();
        let grid = InputGrid::ranged(spec.io.input, spec.domain);
        let (lo, hi) = grid.raw_bounds();
        let xs: Vec<i64> = (lo..=hi).collect();
        let mut hw_out = vec![0i64; xs.len()];
        let mut golden_out = vec![0i64; xs.len()];
        let stats = hw.eval_raw(&spec, &xs, &mut hw_out).unwrap();
        golden.eval_raw(&spec, &xs, &mut golden_out).unwrap();
        assert!(stats.sim_cycles > 0, "{spec}: no cycle accounting");
        for (i, (&a, &b)) in hw_out.iter().zip(&golden_out).enumerate() {
            assert_eq!(a, b, "{spec} at raw {} (index {i})", xs[i]);
        }
    }
}

#[test]
fn sim_cycles_nonzero_and_monotone_in_batch_size() {
    // Single-batch (cold-stream) cost as a function of batch size:
    // always nonzero, strictly monotone, and exactly the pipelined
    // `latency + N − 1`.
    for spec in [MethodSpec::table1(MethodId::Pwl), MethodSpec::table1(MethodId::Lambert)] {
        let latency = pipeline_for(&spec).unwrap().latency();
        let mut prev = 0u64;
        for n in [1usize, 2, 16, 128, 1024] {
            // A fresh backend per measurement: cold streams make the
            // per-batch numbers comparable across batch sizes.
            let b = HwBackend::new();
            b.ensure(&spec).unwrap();
            let input: Vec<i64> = (0..n as i64).map(|i| (i * 37) % 500).collect();
            let mut out = vec![0i64; n];
            let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
            assert!(stats.sim_cycles > 0, "{spec} n={n}");
            assert!(stats.sim_cycles > prev, "{spec} n={n}: not monotone");
            assert_eq!(stats.sim_cycles, (latency + n - 1) as u64, "{spec} n={n}");
            prev = stats.sim_cycles;
        }
    }
}

#[test]
fn streaming_steady_state_cheaper_than_single_batch() {
    // The streaming contract on one shared backend: the first batch
    // pays the fill latency, every warm batch costs exactly N cycles —
    // so steady-state cycles/element ≤ single-batch cycles/element,
    // with identical output bits either way.
    for spec in MethodSpec::table1_all() {
        let b = HwBackend::new();
        b.ensure(&spec).unwrap();
        let latency = b.pipeline(&spec).unwrap().latency();
        let n = 64usize;
        let input: Vec<i64> = (0..n as i64).map(|i| (i * 311) % 20000 - 10000).collect();
        let mut first_out = vec![0i64; n];
        let mut warm_out = vec![0i64; n];
        let first = b.eval_raw(&spec, &input, &mut first_out).unwrap().sim_cycles;
        let warm = b.eval_raw(&spec, &input, &mut warm_out).unwrap().sim_cycles;
        assert_eq!(first, (latency + n - 1) as u64, "{spec}");
        assert_eq!(warm, n as u64, "{spec}");
        let single_batch = first as f64 / n as f64;
        let steady = warm as f64 / n as f64;
        assert!(steady <= single_batch, "{spec}: {steady} > {single_batch}");
        assert_eq!(first_out, warm_out, "{spec}: warm stream changed bits");
    }
}

#[test]
fn analytic_cost_model_tracks_measured_hw_cycles() {
    // Regression band pinning the §IV analytic model against the
    // lowered datapaths for all six Table I methods. Documented band:
    // measured/analytic latency and critical path within [0.5, 2.0]
    // (today's lowerings sit in ~[0.85, 1.3]); area within an order of
    // magnitude (the analytic inventory prices iterative-reuse
    // dividers, the lowering instantiates unrolled stages). Drift of
    // either side past the band is a modeling or lowering bug.
    let hw = HwBackend::new();
    for spec in MethodSpec::table1_all() {
        let a = analytic_cost(&spec).unwrap();
        let m = hw.probe_cost(&spec).unwrap();
        let cycles_ratio = m.latency_cycles as f64 / a.latency_cycles as f64;
        assert!(
            (0.5..=2.0).contains(&cycles_ratio),
            "{spec}: measured {} vs analytic {} cycles (ratio {cycles_ratio:.2})",
            m.latency_cycles,
            a.latency_cycles
        );
        let delay_ratio = m.stage_delay_fo4 / a.stage_delay_fo4;
        assert!(
            (0.5..=2.0).contains(&delay_ratio),
            "{spec}: measured {:.1} vs analytic {:.1} FO4 (ratio {delay_ratio:.2})",
            m.stage_delay_fo4,
            a.stage_delay_fo4
        );
        let area_ratio = m.area_ge / a.area_ge;
        assert!(
            (0.1..=10.0).contains(&area_ratio),
            "{spec}: measured {:.0} vs analytic {:.0} GE (ratio {area_ratio:.2})",
            m.area_ge,
            a.area_ge
        );
        // The measured steady-state throughput is the §IV.H claim.
        assert_eq!(m.cycles_per_element, 1.0, "{spec}");
    }
}

/// The pre-streaming hw execution path: lower once, then re-fill the
/// pipeline on every batch via `simulate` (per-batch cost
/// `latency + N − 1`). Used as the baseline the streaming worker must
/// beat on the steady scenario.
struct RefillHwBackend {
    pipelines: Mutex<HashMap<MethodSpec, Arc<Pipeline>>>,
}

impl RefillHwBackend {
    fn new() -> RefillHwBackend {
        RefillHwBackend { pipelines: Mutex::new(HashMap::new()) }
    }
}

impl EvalBackend for RefillHwBackend {
    fn name(&self) -> &'static str {
        "hw-refill"
    }
    fn availability(&self) -> Availability {
        Availability::Available
    }
    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        let pipe = pipeline_for(spec).map_err(BackendError::unknown_spec)?;
        self.pipelines.lock().unwrap().insert(*spec, Arc::new(pipe));
        Ok(())
    }
    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        let pipe = self
            .pipelines
            .lock()
            .unwrap()
            .get(spec)
            .cloned()
            .ok_or_else(|| BackendError::unknown_spec(format!("'{spec}' not ensured")))?;
        if input.is_empty() {
            return Ok(EvalStats::default());
        }
        let fxs: Vec<Fx> = input.iter().map(|&raw| Fx::from_raw(raw, spec.io.input)).collect();
        let sim = pipe.simulate(&fxs);
        for (slot, y) in out.iter_mut().zip(&sim.outputs) {
            *slot = y.raw();
        }
        Ok(EvalStats { sim_cycles: sim.cycles as u64, ..EvalStats::default() })
    }
}

#[test]
fn steady_scenario_streaming_beats_per_batch_refill() {
    // The acceptance criterion, end to end: replay the steady
    // scenario's requests through two coordinators — the streaming hw
    // backend vs a per-batch re-filling baseline — and compare
    // steady-state cycles per fed element. Requests are served
    // sequentially so batching is deterministic (each 64-element
    // request is one full 64-element batch on both sides).
    let specs = MethodSpec::table1_all();
    let trace = build_trace("steady", 42, 64, 0.1, &specs).unwrap();
    assert!(trace.requests.len() >= 10 * specs.len());
    let run = |backend: Arc<dyn EvalBackend>| {
        let cfg = CoordinatorConfig {
            shards: 1,
            specs: specs.clone(),
            ..CoordinatorConfig::with_batch(64)
        };
        let coord = Coordinator::start(backend, cfg).unwrap();
        for req in &trace.requests {
            coord.evaluate_spec(&req.spec, req.values.clone()).unwrap();
        }
        let m = coord.metrics();
        coord.shutdown();
        m
    };
    let streaming = run(Arc::new(HwBackend::new()));
    let refill = run(Arc::new(RefillHwBackend::new()));
    // Identical deterministic workload on both sides.
    assert_eq!(streaming.batches, refill.batches);
    assert_eq!(streaming.capacity_elements, refill.capacity_elements);
    assert!(streaming.sim_cycles > 0 && refill.sim_cycles > 0);
    // Streaming pays each spec's fill latency once; re-fill pays it on
    // every batch.
    assert!(
        streaming.sim_cycles_per_element() < refill.sim_cycles_per_element(),
        "streaming {} vs refill {} cycles/element",
        streaming.sim_cycles_per_element(),
        refill.sim_cycles_per_element()
    );
    let fill_overhead: u64 = specs
        .iter()
        .map(|s| pipeline_for(s).unwrap().latency() as u64 - 1)
        .sum();
    assert_eq!(
        streaming.sim_cycles,
        streaming.capacity_elements + fill_overhead,
        "streaming total must be fed elements + one fill per spec stream"
    );
}
