//! Integration tests over the full stack: PJRT runtime loading the
//! JAX/Pallas artifacts, cross-language numerical checks against the
//! python-emitted test vectors, and the coordinator serving through the
//! compiled graphs.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a notice) when the artifact directory is absent so `cargo
//! test` stays green on a fresh checkout.

use std::sync::Arc;

use tanh_vlsi::approx::{table1_suite, MethodId, TanhApprox};
use tanh_vlsi::backend::{EvalBackend, PjrtBackend};
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::runtime::{ArtifactDir, Engine, TensorValue};
use tanh_vlsi::util::json::{self, Json};

fn artifacts_root() -> Option<std::path::PathBuf> {
    // Tests run from the crate root; also accept the env override.
    let p = ArtifactDir::default_path();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    None
}

macro_rules! require_artifacts {
    () => {
        match artifacts_root() {
            Some(p) => p,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn load_vectors(root: &std::path::Path) -> Json {
    let text = std::fs::read_to_string(root.join("test_vectors.json")).unwrap();
    json::parse(&text).unwrap()
}

fn vec_f32(j: &Json) -> Vec<f32> {
    j.as_arr().unwrap().iter().map(|v| v.num().unwrap() as f32).collect()
}

fn vec_i32(j: &Json) -> Vec<i32> {
    j.as_arr().unwrap().iter().map(|v| v.num().unwrap() as i32).collect()
}

// Tests are single-threaded per engine, so they drive `runtime::Engine`
// directly; the engine-thread indirection (PJRT handles are not `Send`)
// lives in `backend::PjrtBackend`, which the coordinator test uses.
fn spawn_engine(root: &std::path::Path) -> Engine {
    Engine::cpu(ArtifactDir::open(root).unwrap()).unwrap()
}

#[test]
fn runtime_executes_every_tanh_graph_matching_python() {
    let root = require_artifacts!();
    let engine = spawn_engine(&root);
    let vectors = load_vectors(&root);
    let xs = vec_f32(vectors.get("tanh_input_f32").unwrap());
    for method in ["pwl", "taylor1", "taylor2", "catmull_rom", "velocity", "lambert", "ref"] {
        let name = format!("tanh_{method}_1024");
        let got = engine.run_f32(&name, xs.clone()).unwrap();
        let want = vec_f32(vectors.get("tanh_expected").unwrap().get(method).unwrap());
        assert_eq!(got.len(), want.len(), "{name}");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            // Exactly the same HLO graph python executed; CPU PJRT is
            // deterministic, so this is equality, not allclose.
            assert_eq!(g, w, "{name}[{i}]: rust {g} vs python {w}");
        }
    }
}

#[test]
fn pwl_raw_graph_is_bit_exact_against_rust_golden_model() {
    // The flagship cross-language claim: the Pallas int32 PWL kernel,
    // AOT-lowered and executed by the rust PJRT runtime, reproduces the
    // rust fixed-point golden datapath raw-word for raw-word.
    let root = require_artifacts!();
    let engine = spawn_engine(&root);
    let vectors = load_vectors(&root);
    let raw_in = vec_i32(vectors.get("tanh_raw_input").unwrap());
    let out = engine
        .load("tanh_pwl_raw_1024")
        .unwrap()
        .execute(&[TensorValue::I32(raw_in.clone())])
        .unwrap();
    let got = out[0].as_i32().unwrap();

    // python-recorded expectation
    let want = vec_i32(vectors.get("tanh_raw_expected").unwrap());
    assert_eq!(got, &want[..], "rust-PJRT vs python execution");

    // rust golden model expectation
    let golden = tanh_vlsi::approx::pwl::Pwl::table1();
    for (i, &raw) in raw_in.iter().enumerate() {
        let x = Fx::from_raw(raw as i64, QFormat::S3_12);
        let want = golden.eval_fx(x, QFormat::S_15).raw() as i32;
        assert_eq!(got[i], want, "raw {raw}: pallas {} vs golden {want}", got[i]);
    }
}

#[test]
fn lstm_logits_graph_matches_python_and_classifies() {
    let root = require_artifacts!();
    let engine = spawn_engine(&root);
    let vectors = load_vectors(&root);
    let lstm = vectors.get("lstm").unwrap();
    let seq = vec_f32(lstm.get("seq").unwrap());
    let labels = vec_i32(lstm.get("labels").unwrap());

    for method in ["ref", "pwl"] {
        let name = format!("lstm_logits_{method}");
        let out =
            engine.load(&name).unwrap().execute(&[TensorValue::F32(seq.clone())]).unwrap();
        let logits = out[0].as_f32().unwrap();
        let want = vec_f32(lstm.get(&format!("logits_{method}")).unwrap());
        // 16 chained matmuls: the two XLA versions fuse/reassociate
        // differently, so this is allclose (≈1e-7 per step), not eq.
        assert_eq!(logits.len(), want.len());
        for (i, (g, w)) in logits.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "{name}[{i}]: {g} vs {w}");
        }

        // The trained model must actually classify (≥75% on this batch).
        let correct = labels
            .iter()
            .enumerate()
            .filter(|(i, &l)| {
                let pred = if logits[2 * i + 1] > logits[2 * i] { 1 } else { 0 };
                pred == l
            })
            .count();
        let acc = correct as f64 / labels.len() as f64;
        assert!(acc >= 0.75, "{name}: accuracy {acc}");
    }
}

#[test]
fn approx_lstm_matches_exact_lstm_predictions() {
    // End-to-end approximation-impact check: PWL-activations LSTM must
    // agree with the exact-tanh LSTM on (almost) every prediction.
    let root = require_artifacts!();
    let vectors = load_vectors(&root);
    let lstm = vectors.get("lstm").unwrap();
    let l_ref = vec_f32(lstm.get("logits_ref").unwrap());
    let l_pwl = vec_f32(lstm.get("logits_pwl").unwrap());
    let n = l_ref.len() / 2;
    let mut agree = 0;
    for i in 0..n {
        let p_ref = l_ref[2 * i + 1] > l_ref[2 * i];
        let p_pwl = l_pwl[2 * i + 1] > l_pwl[2 * i];
        if p_ref == p_pwl {
            agree += 1;
        }
    }
    assert!(agree as f64 / n as f64 >= 0.95, "agreement {agree}/{n}");
    // and the raw logits stay close
    let max_dev = l_ref
        .iter()
        .zip(&l_pwl)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 0.1, "max logit deviation {max_dev}");
}

#[test]
fn coordinator_serves_through_compiled_graphs() {
    let root = require_artifacts!();
    let backend = PjrtBackend::new(&root, 1024);
    if !backend.availability().is_available() {
        // Artifacts exist but the xla bindings are stubbed: the typed
        // fail-fast path is covered by the unit tests; nothing to
        // serve here.
        eprintln!("skipping: pjrt backend unavailable in this build");
        return;
    }
    let coord =
        Coordinator::start(Arc::new(backend), CoordinatorConfig::with_batch(1024)).unwrap();

    // Mixed-method concurrent load; every reply must match the golden
    // model within the f32 band.
    let goldens: Vec<_> = table1_suite();
    let mut receivers = Vec::new();
    for (i, method) in MethodId::all().into_iter().cycle().take(24).enumerate() {
        let values: Vec<f32> = (0..37).map(|j| ((i * 37 + j) as f32) * 0.01 - 3.0).collect();
        receivers.push((method, values.clone(), coord.submit(method, values).unwrap()));
    }
    for (method, values, rx) in receivers {
        let out = rx.recv().unwrap().expect_values();
        let golden = goldens.iter().find(|g| g.id() == method).unwrap();
        for (x, y) in values.iter().zip(&out) {
            let want = golden.eval_fx(Fx::from_f64(*x as f64, QFormat::S3_12), QFormat::S_15);
            // f32 kernel vs fixed-point golden: the kernels compute in
            // f32 without output quantization, so allow the method's
            // Table I band plus quantization.
            assert!(
                (want.to_f64() - *y as f64).abs() < 3e-4,
                "{method:?} x={x}: pjrt {y} golden {}",
                want.to_f64()
            );
        }
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 24);
    assert_eq!(m.errors, 0);
    assert!(m.batch_efficiency() > 0.0);
    coord.shutdown();
}

#[test]
fn engine_reports_platform_and_rejects_unknown_artifacts() {
    let root = require_artifacts!();
    let engine = spawn_engine(&root);
    assert!(!engine.platform().is_empty());
    assert!(engine.run_f32("nope_123", vec![0.0]).is_err());
    // shape mismatch is rejected before reaching PJRT
    assert!(engine.run_f32("tanh_pwl_1024", vec![0.0; 7]).is_err());
}
