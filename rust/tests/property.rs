//! Cross-module property tests: invariants that must hold across the
//! whole approximation suite, randomized over configurations — plus
//! batcher-invariant properties and failure injection for the
//! coordinator.

use std::sync::Arc;

use tanh_vlsi::approx::{
    build, eval_odd_saturating, table1_suite, ActSpec, IoSpec, MethodId, MethodSpec, TanhApprox,
};
use tanh_vlsi::backend::{
    Availability, BackendError, ErrorCode, EvalBackend, EvalStats, GoldenBackend, HwBackend,
};
use tanh_vlsi::bench::scenario::GoldenVerifier;
use tanh_vlsi::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, PendingBatch, Request, RequestErrorKind,
};
use tanh_vlsi::error::{measure_with_threads, InputGrid};
use tanh_vlsi::fixed::{fx_add, fx_mul, Fx, QFormat, Round};
use tanh_vlsi::hw::table1_pipeline;
use tanh_vlsi::util::proptest::{prop_check, Prng};

const INP: QFormat = QFormat::S3_12;
const OUT: QFormat = QFormat::S_15;

#[test]
fn compiled_kernels_bit_exact_on_full_table1_grid() {
    // The tentpole invariant: for every method, the compiled batch
    // kernel reproduces the scalar golden datapath raw-for-raw over the
    // entire exhaustive Table I grid (every S3.12 word in ±6).
    let io = IoSpec::table1();
    let grid = InputGrid::table1();
    let (lo, hi) = grid.raw_bounds();
    let xs: Vec<i64> = (lo..=hi).collect();
    for m in table1_suite() {
        let kernel = m.compile(io);
        let mut ys = vec![0i64; xs.len()];
        kernel.eval_slice_raw(&xs, &mut ys);
        for (&raw, &y) in xs.iter().zip(&ys) {
            let want = m.eval_fx(Fx::from_raw(raw, io.input), io.output).raw();
            assert_eq!(y, want, "{} at raw {raw}", m.describe());
        }
    }
}

#[test]
fn packed_kernels_bit_exact_on_full_table1_grid() {
    // The SWAR invariant: for every Table I method the packed 4×16-bit
    // entry point reproduces the scalar slice path raw-for-raw over the
    // entire exhaustive grid (every S3.12 word in ±6), which transitively
    // pins it to the golden datapath via the test above.
    let io = IoSpec::table1();
    let grid = InputGrid::table1();
    let (lo, hi) = grid.raw_bounds();
    let xs: Vec<i64> = (lo..=hi).collect();
    for m in table1_suite() {
        let kernel = m.compile(io);
        assert_eq!(
            kernel.lane_width(),
            Some(16),
            "{}: Table I formats must select 16-bit lanes",
            m.describe()
        );
        let mut scalar = vec![0i64; xs.len()];
        let mut packed = vec![0i64; xs.len()];
        kernel.eval_slice_raw(&xs, &mut scalar);
        kernel.eval_slice_packed(&xs, &mut packed);
        for (i, (&a, &b)) in scalar.iter().zip(&packed).enumerate() {
            assert_eq!(a, b, "{} at raw {}", m.describe(), xs[i]);
        }
    }
}

#[test]
fn packed_kernels_bit_exact_on_edges_and_odd_lengths() {
    // Targeted adversarial inputs for the SWAR front end: the format's
    // min_raw (whose absolute value needs the lane's full unsigned
    // range), both saturation boundaries, and slice lengths that leave
    // 1..3-lane scalar tails — plus the empty slice.
    for m in table1_suite() {
        let io = IoSpec::table1();
        let kernel = m.compile(io);
        let (in_max, dom) = (io.input.max_raw(), kernel.domain_raw());
        let mut edges = vec![0i64, 1, -1, in_max, -in_max, io.input.min_raw()];
        for d in [dom - 1, dom, dom + 1] {
            if d <= in_max {
                edges.push(d);
                edges.push(-d);
            }
        }
        for n in [0usize, 1, 2, 3, 4, 5, 7, 9, edges.len()] {
            let xs: Vec<i64> = edges.iter().cycle().take(n).copied().collect();
            let mut scalar = vec![0i64; n];
            let mut packed = vec![0i64; n];
            kernel.eval_slice_raw(&xs, &mut scalar);
            kernel.eval_slice_packed(&xs, &mut packed);
            assert_eq!(scalar, packed, "{} with {n} edge inputs", m.describe());
        }
    }
}

#[test]
fn prop_packed_matches_scalar_random_configs() {
    // Beyond Table I: random design points over the narrow (8-bit
    // lanes), standard (16-bit lanes) and wide (scalar fallback) format
    // pairs must agree packed-vs-scalar on random slices of random
    // lengths. The wide pair proves the fallback is transparent.
    prop_check("packed == scalar on random configs", 40, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let io = *g.choose(&[
            IoSpec::table1(),
            IoSpec { input: QFormat::S2_13, output: QFormat::S_15 },
            IoSpec { input: QFormat::S2_5, output: QFormat::S_7 },
            IoSpec { input: QFormat::S3_12, output: QFormat::S7_24 },
        ]);
        let k_max = 7.min(io.input.frac_bits as i64 - 1);
        let param = match id {
            MethodId::Lambert => g.i64_in(2, 10) as f64,
            _ => (2f64).powi(-g.i64_in(2, k_max) as i32),
        };
        let domain = if io.input.frac_bits >= 12 { 6.0 } else { 4.0 };
        let m = build(id, param, domain).map_err(|e| format!("build {id:?} {param}: {e}"))?;
        let kernel = m.compile(io);
        if io.output == QFormat::S7_24 && kernel.lane_width().is_some() {
            return Err(format!("{}: 33-bit output cannot fit a 16-bit lane", m.describe()));
        }
        let n = g.usize_below(67);
        let xs: Vec<i64> =
            (0..n).map(|_| g.i64_in(io.input.min_raw(), io.input.max_raw())).collect();
        let mut scalar = vec![0i64; n];
        let mut packed = vec![0i64; n];
        kernel.eval_slice_raw(&xs, &mut scalar);
        kernel.eval_slice_packed(&xs, &mut packed);
        for (i, (&a, &b)) in scalar.iter().zip(&packed).enumerate() {
            if a != b {
                return Err(format!(
                    "{} {}->{} (lanes {:?}) raw {}: scalar {a} vs packed {b}",
                    m.describe(),
                    io.input,
                    io.output,
                    kernel.lane_width(),
                    xs[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn hw_backend_bit_exact_vs_golden_kernel_on_full_table1_grid() {
    // The cross-backend property of the unified execution layer: for
    // all six Table I specs, the cycle-accurate hw backend produces
    // the same raw words as the golden compiled kernel on EVERY input
    // the grid can express — the two backends are interchangeable
    // realizations of the same design point, bit for bit.
    let hw = HwBackend::new();
    let golden = GoldenBackend::new();
    let grid = InputGrid::table1();
    let (lo, hi) = grid.raw_bounds();
    let xs: Vec<i64> = (lo..=hi).collect();
    for spec in MethodSpec::table1_all() {
        hw.ensure(&spec).unwrap();
        golden.ensure(&spec).unwrap();
        let mut hw_out = vec![0i64; xs.len()];
        let mut golden_out = vec![0i64; xs.len()];
        let stats = hw.eval_raw(&spec, &xs, &mut hw_out).unwrap();
        golden.eval_raw(&spec, &xs, &mut golden_out).unwrap();
        assert!(stats.sim_cycles >= xs.len() as u64, "{spec}: pipelined ⇒ ≥ 1 cycle/input");
        for (i, (&a, &b)) in hw_out.iter().zip(&golden_out).enumerate() {
            assert_eq!(a, b, "{spec} at raw {} (index {i})", xs[i]);
        }
    }
}

#[test]
fn parallel_measure_identical_to_sequential_for_all_methods() {
    // Fixed-size chunking + in-order Accum merging make the parallel
    // sweep deterministic: every field must match the single-threaded
    // result bit-for-bit, for every method (different kernel shapes).
    let grid = InputGrid::table1();
    for m in table1_suite() {
        let seq = measure_with_threads(m.as_ref(), grid, OUT, 1);
        let par = measure_with_threads(m.as_ref(), grid, OUT, 4);
        assert_eq!(seq.max_abs, par.max_abs, "{}", m.describe());
        assert_eq!(seq.argmax, par.argmax, "{}", m.describe());
        assert_eq!(seq.mse, par.mse, "{}", m.describe());
        assert_eq!(seq.rms, par.rms, "{}", m.describe());
        assert_eq!(seq.mean_abs, par.mean_abs, "{}", m.describe());
        assert_eq!(seq.max_ulp, par.max_ulp, "{}", m.describe());
        assert_eq!(seq.points, par.points, "{}", m.describe());
    }
}

#[test]
fn prop_compiled_kernels_bit_exact_random_configs() {
    // Beyond the Table I configurations: random parameters and the
    // Table III format pairs must also compile bit-exactly (structured
    // kernels where the decode exists, tabulation fallback otherwise).
    prop_check("compiled == scalar on random configs", 40, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let io = *g.choose(&[
            IoSpec::table1(),
            IoSpec { input: QFormat::S2_13, output: QFormat::S_15 },
            IoSpec { input: QFormat::S2_5, output: QFormat::S_7 },
        ]);
        // A step of 2^-k needs k addressable input fraction bits, and
        // centred Taylor anchors need one t bit on top (the scalar
        // datapath cannot decode finer steps either).
        let k_max = 7.min(io.input.frac_bits as i64 - 1);
        let param = match id {
            MethodId::Lambert => g.i64_in(2, 10) as f64,
            _ => (2f64).powi(-g.i64_in(2, k_max) as i32),
        };
        let domain = if io.input == QFormat::S3_12 { 6.0 } else { 4.0 };
        let m = build(id, param, domain).map_err(|e| format!("build {id:?} {param}: {e}"))?;
        let kernel = m.compile(io);
        for _ in 0..64 {
            let raw = g.i64_in(io.input.min_raw(), io.input.max_raw());
            let want = m.eval_fx(Fx::from_raw(raw, io.input), io.output).raw();
            let got = kernel.eval_raw(raw);
            if got != want {
                return Err(format!(
                    "{} {}->{} raw {raw}: kernel {got} vs scalar {want}",
                    m.describe(),
                    io.input,
                    io.output
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_output_bounded_by_one_for_all_methods_and_params() {
    // |tanh| < 1 must survive any configuration, any input.
    prop_check("output magnitude ≤ max_raw", 300, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let param = match id {
            MethodId::Lambert => g.i64_in(1, 12) as f64,
            _ => (2f64).powi(-g.i64_in(2, 8) as i32),
        };
        let m = build(id, param, 6.0).map_err(|e| format!("build {id:?} {param}: {e}"))?;
        for _ in 0..20 {
            let x = Fx::from_raw(g.i64_in(INP.min_raw(), INP.max_raw()), INP);
            let y = m.eval_fx(x, OUT);
            if y.raw().abs() > OUT.max_raw() {
                return Err(format!("{}: |{}| > max at x={}", m.describe(), y.raw(), x.to_f64()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_odd_symmetry_random_configs() {
    prop_check("odd symmetry", 200, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let param = match id {
            MethodId::Lambert => g.i64_in(2, 10) as f64,
            _ => (2f64).powi(-g.i64_in(3, 8) as i32),
        };
        let m = build(id, param, 6.0).map_err(|e| format!("build {id:?} {param}: {e}"))?;
        let raw = g.i64_in(0, INP.max_raw());
        let xp = Fx::from_raw(raw, INP);
        let xn = Fx::from_raw(-raw, INP);
        let (yp, yn) = (m.eval_fx(xp, OUT), m.eval_fx(xn, OUT));
        if yp.raw() != -yn.raw() {
            return Err(format!("{} at raw {raw}: {} vs {}", m.describe(), yp.raw(), yn.raw()));
        }
        Ok(())
    });
}

#[test]
fn prop_error_bounded_by_method_band() {
    // Any Table I method must stay within 4 ulp everywhere (the paper's
    // band is ~1.6 ulp; 4 is the hard invariant).
    prop_check("error ≤ 4 ulp", 400, |g: &mut Prng| {
        let suite = table1_suite();
        let m = &suite[g.usize_below(suite.len())];
        let x = Fx::from_raw(g.i64_in(INP.min_raw(), INP.max_raw()), INP);
        let y = m.eval_fx(x, OUT);
        let err = (y.to_f64() - x.to_f64().tanh()).abs();
        if err > 4.0 * OUT.ulp() {
            return Err(format!("{} x={}: err {err}", m.describe(), x.to_f64()));
        }
        Ok(())
    });
}

#[test]
fn prop_pipelines_match_goldens_fuzzed() {
    // The hw pipelines are re-checked with random (not strided) inputs.
    let suite = table1_suite();
    let pipes: Vec<_> = MethodId::all()
        .into_iter()
        .map(|id| table1_pipeline(id, OUT))
        .collect();
    prop_check("pipeline == golden", 500, |g: &mut Prng| {
        let i = g.usize_below(6);
        let x = Fx::from_raw(g.i64_in(INP.min_raw(), INP.max_raw()), INP);
        let want = suite[i].eval_fx(x, OUT);
        let got = pipes[i].eval(x);
        if got.raw() != want.raw() {
            return Err(format!(
                "{} x={}: pipeline {} vs golden {}",
                suite[i].describe(),
                x.to_f64(),
                got.to_f64(),
                want.to_f64()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_monotone_pwl_and_catmull() {
    // Interpolants of a monotone function through monotone data stay
    // monotone for PWL; Catmull-Rom can overshoot only between control
    // points whose slope changes sign — never the case for tanh. Check
    // on random adjacent pairs.
    let methods: Vec<Box<dyn TanhApprox>> = vec![
        Box::new(tanh_vlsi::approx::pwl::Pwl::table1()),
        Box::new(tanh_vlsi::approx::catmull_rom::CatmullRom::table1()),
    ];
    prop_check("local monotonicity", 500, |g: &mut Prng| {
        let m = &methods[g.usize_below(2)];
        let raw = g.i64_in(INP.min_raw(), INP.max_raw() - 1);
        let y0 = eval_odd_saturating(m.as_ref(), Fx::from_raw(raw, INP), OUT);
        let y1 = eval_odd_saturating(m.as_ref(), Fx::from_raw(raw + 1, INP), OUT);
        if y1.raw() < y0.raw() {
            return Err(format!("{} inversion at raw {raw}", m.describe()));
        }
        Ok(())
    });
}

#[test]
fn prop_grid_strides_preserve_bounds() {
    // A strided sweep can only under-report, never over-report, the max
    // error of a full sweep.
    let grid = InputGrid::table1();
    let pwl = tanh_vlsi::approx::pwl::Pwl::table1();
    let full = tanh_vlsi::error::measure(&pwl, grid, OUT);
    prop_check("strided ≤ full", 10, |g: &mut Prng| {
        let stride = 2 + g.usize_below(64);
        let mut max: f64 = 0.0;
        for x in grid.iter_strided(stride) {
            let y = pwl.eval_fx(x, OUT);
            max = max.max((y.to_f64() - x.to_f64().tanh()).abs());
        }
        if max > full.max_abs + 1e-15 {
            return Err(format!("stride {stride}: {max} > {}", full.max_abs));
        }
        Ok(())
    });
}

#[test]
fn prop_method_spec_display_parse_round_trip() {
    // The serialization contract: for any valid design point of any of
    // the six methods, `parse(to_string()) == spec` (equality = the
    // canonical key, so io formats and domain survive too).
    let formats = [
        IoSpec::table1(),
        IoSpec { input: QFormat::S2_13, output: QFormat::S_15 },
        IoSpec { input: QFormat::S2_5, output: QFormat::S_7 },
        IoSpec { input: QFormat::S3_12, output: QFormat::S_7 },
    ];
    let domains = [1.0, 4.0, 5.5, 6.0, 8.0];
    prop_check("MethodSpec::parse(spec.to_string()) == spec", 300, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let io = *g.choose(&formats);
        let domain = *g.choose(&domains);
        let frac = io.input.frac_bits as i64;
        let param = match id {
            MethodId::Lambert => g.i64_in(1, 16) as f64,
            MethodId::TaylorQuadratic | MethodId::TaylorCubic => {
                (2f64).powi(-g.i64_in(1, frac - 1) as i32)
            }
            _ => (2f64).powi(-g.i64_in(0, frac) as i32),
        };
        let spec = MethodSpec::with_param(id, param, io, domain)
            .map_err(|e| format!("{id:?} param {param} {io:?} dom {domain}: {e}"))?;
        let text = spec.to_string();
        let back = MethodSpec::parse(&text)
            .map_err(|e| format!("'{text}' failed to re-parse: {e}"))?;
        if back != spec {
            return Err(format!("'{text}' round-tripped to '{back}'"));
        }
        if back.method_id() != id || back.param() != param {
            return Err(format!("'{text}' lost its parameter"));
        }
        Ok(())
    });
}

#[test]
fn prop_spec_rejections() {
    // Malformed design points must be errors, not silent corrections.
    for bad in [
        "pwl:step=3",
        "pwl:step=1/3",
        "pwl:step=0",
        "catmull:step=-0.5",
        "velocity:threshold=0.3",
        "lambert:terms=0",
        "lambert:terms=2.5",
        "lambert:terms=-4",
        "pwl:in=x3.2",
        "pwl:out=Q15",
        "pwl:dom=-6",
        "pwl:dom=0",
        "taylor1:step=1/4096", // no expansion bits left in S3.12
        "nope:step=1/2",
    ] {
        assert!(MethodSpec::parse(bad).is_err(), "'{bad}' should be rejected");
    }
}

#[test]
fn prop_act_spec_display_parse_round_trip() {
    // The activation-level contract on top of the method contract: for
    // any valid inner design point and either activation kind,
    // `ActSpec::parse(act.to_string()) == act` — the `sig:` prefix
    // survives exactly one round and never stacks.
    let formats = [
        IoSpec::table1(),
        IoSpec { input: QFormat::S2_13, output: QFormat::S_15 },
        IoSpec { input: QFormat::S2_5, output: QFormat::S_7 },
    ];
    let domains = [4.0, 6.0, 8.0];
    prop_check("ActSpec::parse(act.to_string()) == act", 300, |g: &mut Prng| {
        let id = *g.choose(&MethodId::all());
        let io = *g.choose(&formats);
        let domain = *g.choose(&domains);
        let frac = io.input.frac_bits as i64;
        let param = match id {
            MethodId::Lambert => g.i64_in(1, 16) as f64,
            MethodId::TaylorQuadratic | MethodId::TaylorCubic => {
                (2f64).powi(-g.i64_in(1, frac - 1) as i32)
            }
            _ => (2f64).powi(-g.i64_in(0, frac) as i32),
        };
        let spec = MethodSpec::with_param(id, param, io, domain)
            .map_err(|e| format!("{id:?} param {param}: {e}"))?;
        let sigmoid = g.bool(0.5);
        let act = if sigmoid { ActSpec::sigmoid(spec) } else { ActSpec::tanh(spec) };
        let text = act.to_string();
        if sigmoid != text.starts_with("sig:") {
            return Err(format!("'{text}' mislabels kind {:?}", act.kind));
        }
        let back = ActSpec::parse(&text)
            .map_err(|e| format!("'{text}' failed to re-parse: {e}"))?;
        if back != act {
            return Err(format!("'{text}' round-tripped to '{back}'"));
        }
        if back.spec != spec {
            return Err(format!("'{text}' lost its inner design point"));
        }
        Ok(())
    });
}

#[test]
fn prop_act_spec_rejections() {
    // A stacked or malformed inner spec must be an error, not a
    // silently-corrected activation.
    for bad in [
        "sig:sig:pwl:step=1/64", // the prefix never stacks
        "sig:nope:step=1/2",     // unknown inner method
        "sig:",                  // empty inner spec
        "sig:pwl:step=1/3",      // inner step not a reciprocal power of two
        "sig:table1:Z",          // unknown Table I row
    ] {
        assert!(ActSpec::parse(bad).is_err(), "'{bad}' should be rejected");
    }
}

// ---------- Pareto frontier invariants ----------

mod pareto_props {
    use super::*;
    use tanh_vlsi::backend::CostSource;
    use tanh_vlsi::explore::{dominates_by, pareto_frontier_by, DesignPoint, Objective};

    fn random_point(g: &mut Prng, constant_area: bool) -> DesignPoint {
        DesignPoint {
            spec: MethodSpec::table1(MethodId::Pwl),
            id: MethodId::Pwl,
            param: 0.0,
            max_err: g.f64_in(1e-6, 1e-3),
            rms: g.f64_in(1e-7, 1e-4),
            area_ge: if constant_area { 500.0 } else { g.f64_in(100.0, 5000.0) },
            latency_cycles: g.i64_in(1, 20) as u32,
            stage_delay_fo4: g.f64_in(5.0, 30.0),
            cycles_per_element: g.f64_in(1.0, 4.0),
            cost_source: CostSource::Analytic,
        }
    }

    /// Total comparison key: every objective axis value, so two
    /// frontiers can be compared as multisets regardless of tie order.
    fn key(p: &DesignPoint) -> [f64; 6] {
        [
            p.max_err,
            p.rms,
            p.area_ge,
            p.latency_cycles as f64,
            p.cycles_per_element,
            p.stage_delay_fo4,
        ]
    }

    fn sorted_keys(points: &[DesignPoint]) -> Vec<[f64; 6]> {
        let mut keys: Vec<[f64; 6]> = points.iter().map(key).collect();
        keys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        keys
    }

    #[test]
    fn prop_pareto_frontier_sound_under_any_objective_set() {
        let axes_pool: [&[Objective]; 4] = [
            &[Objective::MaxErr, Objective::Area, Objective::Cycles],
            &[Objective::MaxErr, Objective::Cycles],
            &[Objective::Rms, Objective::Area, Objective::Delay, Objective::CyclesPerElement],
            &[Objective::MaxErr],
        ];
        prop_check("pareto frontier sound", 120, |g: &mut Prng| {
            let axes = axes_pool[g.usize_below(axes_pool.len())];
            // A quarter of the cases pin one axis constant across the
            // whole set: the frontier must degrade gracefully to the
            // remaining axes instead of collapsing or blowing up.
            let constant_area = g.bool(0.25);
            let n = 1 + g.usize_below(40);
            let points: Vec<DesignPoint> =
                (0..n).map(|_| random_point(g, constant_area)).collect();
            let frontier = pareto_frontier_by(&points, axes);
            if frontier.is_empty() {
                return Err("frontier of a non-empty set is empty".into());
            }
            // Mutually non-dominated.
            for (i, a) in frontier.iter().enumerate() {
                for b in &frontier {
                    if dominates_by(a, b, axes) && dominates_by(b, a, axes) {
                        return Err("mutual domination is contradictory".into());
                    }
                    if dominates_by(b, a, axes) {
                        return Err(format!("frontier point {i} is dominated"));
                    }
                }
            }
            // Every dropped point is dominated by some frontier point
            // (dominance is a strict partial order, so a maximal
            // dominator exists and survives into the frontier).
            for p in &points {
                let dropped = points.iter().any(|q| dominates_by(q, p, axes));
                if dropped && !frontier.iter().any(|f| dominates_by(f, p, axes)) {
                    return Err("dropped point not dominated by the frontier".into());
                }
                if !dropped {
                    // Non-dominated points must appear in the frontier.
                    let k = key(p);
                    if !frontier.iter().any(|f| key(f) == k) {
                        return Err("non-dominated point missing from frontier".into());
                    }
                }
            }
            // Invariant under input permutation (Fisher-Yates on a copy).
            let mut shuffled = points.clone();
            for i in (1..shuffled.len()).rev() {
                shuffled.swap(i, g.usize_below(i + 1));
            }
            let refrontier = pareto_frontier_by(&shuffled, axes);
            if sorted_keys(&frontier) != sorted_keys(&refrontier) {
                return Err("frontier changed under input permutation".into());
            }
            // Sorted by the first objective.
            let first = axes[0];
            if !frontier.windows(2).all(|w| first.value(&w[0]) <= first.value(&w[1])) {
                return Err("frontier not sorted by the first objective".into());
            }
            Ok(())
        });
    }

    #[test]
    fn constant_axis_matches_frontier_without_that_axis() {
        // With an axis constant across the set, the frontier must be
        // exactly what the remaining axes alone produce.
        prop_check("constant axis is a no-op", 40, |g: &mut Prng| {
            let n = 2 + g.usize_below(30);
            let points: Vec<DesignPoint> = (0..n).map(|_| random_point(g, true)).collect();
            let with = pareto_frontier_by(
                &points,
                &[Objective::MaxErr, Objective::Area, Objective::Cycles],
            );
            let without =
                pareto_frontier_by(&points, &[Objective::MaxErr, Objective::Cycles]);
            if sorted_keys(&with) != sorted_keys(&without) {
                return Err(format!(
                    "constant area axis changed the frontier: {} vs {} points",
                    with.len(),
                    without.len()
                ));
            }
            Ok(())
        });
    }
}

// ---------- batcher invariants ----------

/// Builds a standalone request (the reply receiver is dropped; these
/// tests never flush through a worker).
fn bare_request(id: u64, n: usize) -> Request {
    let (tx, _rx) = std::sync::mpsc::channel();
    Request {
        id,
        spec: MethodSpec::table1(MethodId::Pwl),
        values: (0..n).map(|i| (id as f32) + (i as f32) * 1e-3).collect(),
        enqueued_at: std::time::Instant::now(),
        reply: tx,
    }
}

#[test]
fn prop_pack_never_splits_requests_and_preserves_order() {
    // Random request mixes packed under the fits() discipline: every
    // request occupies one contiguous span, spans appear in push order
    // head-to-tail, and the remainder of the flat batch is zero pad.
    prop_check("pack is whole, ordered, padded", 100, |g: &mut Prng| {
        let capacity = 1 << (4 + g.usize_below(7)); // 16..=1024
        let mut batch = PendingBatch::default();
        let mut pushed: Vec<(u64, usize)> = Vec::new();
        for id in 0..64 {
            let n = 1 + g.usize_below(capacity);
            let req = bare_request(id, n);
            if !batch.fits(&req, capacity) {
                break;
            }
            pushed.push((id, n));
            batch.push(req);
        }
        let (flat, spans) = batch.pack(capacity);
        if flat.len() != capacity {
            return Err(format!("flat {} != capacity {capacity}", flat.len()));
        }
        if spans.len() != pushed.len() {
            return Err(format!("{} spans for {} requests", spans.len(), pushed.len()));
        }
        let mut cursor = 0usize;
        for (k, ((id, n), &(off, len))) in pushed.iter().zip(&spans).enumerate() {
            if off != cursor || len != *n {
                return Err(format!(
                    "request {k} (id {id}) span ({off}, {len}) vs expected ({cursor}, {n})"
                ));
            }
            // The packed values are the request's own, in order.
            for i in 0..len {
                let want = (*id as f32) + (i as f32) * 1e-3;
                if flat[off + i] != want {
                    return Err(format!("flat[{}] = {} != {want}", off + i, flat[off + i]));
                }
            }
            cursor += len;
        }
        if flat[cursor..].iter().any(|&v| v != 0.0) {
            return Err("padding tail is not all zeros".into());
        }
        if batch.elements != cursor {
            return Err(format!("elements {} != packed {cursor}", batch.elements));
        }
        Ok(())
    });
}

#[test]
fn prop_fits_is_exact_at_capacity() {
    prop_check("fits == (elements + len <= capacity)", 200, |g: &mut Prng| {
        let capacity = 8 + g.usize_below(2048);
        let mut batch = PendingBatch::default();
        let pre = g.usize_below(capacity);
        if pre > 0 {
            batch.push(bare_request(0, pre));
        }
        let n = 1 + g.usize_below(2 * capacity);
        let fits = batch.fits(&bare_request(1, n), capacity);
        let want = pre + n <= capacity;
        if fits != want {
            return Err(format!("capacity {capacity}, pre {pre}, n {n}: fits={fits}"));
        }
        Ok(())
    });
}

#[test]
fn max_wait_flush_fires_on_partial_batches() {
    use std::time::{Duration, Instant};
    let cfg = BatcherConfig {
        batch_elements: 1024,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    };
    let mut batch = PendingBatch::default();
    // Empty batches never flush, no matter how old the clock.
    assert!(!batch.should_flush(&cfg, Instant::now() + Duration::from_secs(5)));
    batch.push(bare_request(0, 10));
    let born = batch.oldest.expect("oldest set on first push");
    // A partial batch holds until max_wait, then flushes.
    assert!(!batch.should_flush(&cfg, born));
    assert!(!batch.should_flush(&cfg, born + Duration::from_micros(199)));
    assert!(batch.should_flush(&cfg, born + Duration::from_micros(200)));
    // A full batch flushes regardless of age.
    batch.push(bare_request(1, 1014));
    assert!(batch.should_flush(&cfg, born));
}

#[test]
fn coordinator_slices_padding_off_round_trip() {
    // End-to-end pack/unpack audit: random-size requests served through
    // the batcher come back with exactly their own outputs (no padding
    // leakage, no neighbor crosstalk), bit-exact vs an independent
    // golden-kernel evaluation.
    let batch = 64;
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig::with_batch(batch),
    )
    .unwrap();
    let verifier = GoldenVerifier::new();
    prop_check("padding sliced off on the way out", 60, |g: &mut Prng| {
        let method = *g.choose(&MethodId::all());
        let n = 1 + g.usize_below(batch);
        let values: Vec<f32> = (0..n).map(|_| g.f64_in(-6.5, 6.5) as f32).collect();
        let out = coord.evaluate(method, values.clone()).map_err(|e| e.to_string())?;
        if out.len() != n {
            return Err(format!("{method:?}: {} outputs for {n} inputs", out.len()));
        }
        let want = verifier.expected(&MethodSpec::table1(method), &values)?;
        for (i, (got, exp)) in out.iter().zip(&want).enumerate() {
            if got.to_bits() != exp.to_bits() {
                return Err(format!("{method:?}[{i}]: {got} != golden {exp}"));
            }
        }
        Ok(())
    });
    coord.shutdown();
}

#[test]
fn oversized_request_fails_deterministically_not_starves() {
    let batch = 32;
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig::with_batch(batch),
    )
    .unwrap();
    // The router rejects oversized requests with the same typed error
    // every time (no silent queueing, no starvation).
    let e1 = coord.submit(MethodId::Pwl, vec![0.0; batch + 1]).unwrap_err();
    let e2 = coord.submit(MethodId::Pwl, vec![0.0; batch + 1]).unwrap_err();
    assert_eq!(e1, e2);
    assert_eq!(e1.kind, RequestErrorKind::Admission);
    assert_eq!(e1.code, ErrorCode::BadRequest);
    assert!(e1.message.contains("exceeds the compiled batch"), "{e1}");
    // An exactly-batch-size request is NOT oversized.
    let out = coord.evaluate(MethodId::Pwl, vec![0.5; batch]).unwrap();
    assert_eq!(out.len(), batch);
    // And normal traffic still flows afterwards — nothing wedged.
    let out = coord.evaluate(MethodId::Lambert, vec![1.0, -1.0]).unwrap();
    assert_eq!(out.len(), 2);
    let m = coord.metrics();
    assert_eq!(m.requests, 2);
    assert_eq!(m.rejected, 0, "oversized is a hard error, not backpressure");
    coord.shutdown();
}

// ---------- failure injection ----------

/// A backend that fails every `fail_every`-th batch with an internal
/// backend error.
struct FlakyBackend {
    inner: GoldenBackend,
    counter: std::sync::atomic::AtomicU64,
    fail_every: u64,
}

impl EvalBackend for FlakyBackend {
    fn name(&self) -> &'static str {
        "flaky-golden"
    }
    fn availability(&self) -> Availability {
        Availability::Available
    }
    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        self.inner.ensure(spec)
    }
    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        let n = self.counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if n % self.fail_every == self.fail_every - 1 {
            return Err(BackendError::internal("injected backend failure"));
        }
        self.inner.eval_raw(spec, input, out)
    }
}

#[test]
fn coordinator_survives_backend_failures() {
    let backend = Arc::new(FlakyBackend {
        inner: GoldenBackend::new(),
        counter: Default::default(),
        fail_every: 3,
    });
    let coord = Coordinator::start(backend, CoordinatorConfig::with_batch(64)).unwrap();
    let mut ok = 0;
    let mut failed = 0;
    for i in 0..60 {
        let rx = coord.submit(MethodId::all()[i % 6], vec![0.5, -0.5]).unwrap();
        match rx.recv().unwrap().outcome {
            Ok(v) => {
                assert_eq!(v.len(), 2);
                ok += 1;
            }
            Err(e) => {
                // The satellite bugfix: a worker-side backend fault is
                // typed as such — distinguishable from admission
                // errors, with the stable `internal` code.
                assert_eq!(e.kind, RequestErrorKind::Backend, "{e}");
                assert_eq!(e.code, ErrorCode::Internal, "{e}");
                assert!(e.message.contains("injected"), "{e}");
                failed += 1;
            }
        }
    }
    // Both outcomes observed; the coordinator never wedged, and the
    // conservation laws reconcile every submit — with the failures
    // counted on the backend side of the split.
    assert!(ok > 0, "no successes");
    assert!(failed > 0, "failure injection never fired");
    let m = coord.metrics();
    assert_eq!(m.submitted, 60);
    assert_eq!(m.requests as usize, ok);
    assert_eq!(m.failed_requests as usize, failed);
    assert_eq!(m.submitted, m.requests + m.failed_requests);
    assert_eq!(m.backend_failed_requests as usize, failed);
    assert_eq!(m.admission_failed_requests, 0);
    assert!(m.errors > 0);
    coord.shutdown();
}

#[test]
fn coordinator_backpressure_rejects_when_flooded() {
    use std::time::Duration;

    /// A backend that is very slow, so the queue fills.
    struct SlowBackend(GoldenBackend);
    impl EvalBackend for SlowBackend {
        fn name(&self) -> &'static str {
            "slow-golden"
        }
        fn availability(&self) -> Availability {
            Availability::Available
        }
        fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
            self.0.ensure(spec)
        }
        fn eval_raw(
            &self,
            spec: &MethodSpec,
            input: &[i64],
            out: &mut [i64],
        ) -> Result<EvalStats, BackendError> {
            std::thread::sleep(Duration::from_millis(20));
            self.0.eval_raw(spec, input, out)
        }
    }

    let coord = Coordinator::start(
        Arc::new(SlowBackend(GoldenBackend::new())),
        CoordinatorConfig {
            batcher: BatcherConfig { batch_elements: 64, max_queue: 256, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    // Flood one method's queue without draining.
    let mut receivers = Vec::new();
    let mut rejected = 0;
    for _ in 0..100 {
        match coord.submit(MethodId::Pwl, vec![0.1; 32]) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                assert_eq!(e.code, ErrorCode::Overloaded, "{e}");
                assert!(e.message.contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "backpressure never engaged");
    // Accepted requests still complete.
    for rx in receivers {
        let _ = rx.recv().unwrap().expect_values();
    }
    assert!(coord.metrics().rejected as usize >= rejected);
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Cell-graph elementwise ops: bit-exact against an independent scalar
// reference over full-format grids.
// ---------------------------------------------------------------------------

/// Independent rounding reference: real-valued result scaled into the
/// destination's raw grid, rounded per mode, clamped. Built from f64
/// arithmetic (exact for every grid below — values are dyadic rationals
/// far inside 2^53) rather than `Round::shift_right`, so it would catch
/// a bug in the bit-twiddled shifts too.
fn quantize_ref(value: f64, dst: QFormat, round: Round) -> i64 {
    let scaled = value * (1i64 << dst.frac_bits) as f64;
    let rounded = match round {
        Round::Trunc => scaled.floor(),
        Round::NearestAway => scaled.round(),
        Round::NearestEven => {
            let f = scaled.floor();
            let d = scaled - f;
            if d < 0.5 {
                f
            } else if d > 0.5 {
                f + 1.0
            } else if (f as i64) % 2 == 0 {
                f
            } else {
                f + 1.0
            }
        }
    };
    (rounded as i64).clamp(dst.min_raw(), dst.max_raw())
}

const ROUNDS: [Round; 3] = [Round::Trunc, Round::NearestAway, Round::NearestEven];

#[test]
fn graph_mul_bit_exact_on_full_grids() {
    // Exact wide product, single rounding into dst: every (a, b) pair
    // of the full S2.5 × S.7 grids, every mode, three destinations
    // (narrowing-with-ties, saturating, and exact pass-through).
    use tanh_vlsi::graph::ops::mul_raw;
    let (af, bf) = (QFormat::S2_5, QFormat::S_7);
    for dst in [QFormat::S_7, QFormat::S2_5, QFormat::S3_12] {
        for round in ROUNDS {
            for a in af.min_raw()..=af.max_raw() {
                for b in bf.min_raw()..=bf.max_raw() {
                    let product = (a as f64 * af.ulp()) * (b as f64 * bf.ulp());
                    let want = quantize_ref(product, dst, round);
                    let got = mul_raw(a, af, b, bf, dst, round);
                    assert_eq!(got, want, "{a}×{b} ({af}×{bf}→{dst}, {})", round.name());
                    // And the wrapper contract: identical to fx_mul.
                    let fx = fx_mul(Fx::from_raw(a, af), Fx::from_raw(b, bf), dst, round);
                    assert_eq!(got, fx.raw());
                }
            }
        }
    }
}

#[test]
fn graph_add_bit_exact_on_full_grids() {
    // fx_add semantics are per-operand conversion *then* a saturating
    // add — the reference mirrors that two-step shape exactly (a
    // single-rounding model would be wrong for narrowing dsts).
    use tanh_vlsi::graph::ops::add_raw;
    let (af, bf) = (QFormat::S2_5, QFormat::S_7);
    for dst in [QFormat::S_7, QFormat::S2_5] {
        for round in ROUNDS {
            for a in af.min_raw()..=af.max_raw() {
                for b in bf.min_raw()..=bf.max_raw() {
                    let qa = quantize_ref(a as f64 * af.ulp(), dst, round);
                    let qb = quantize_ref(b as f64 * bf.ulp(), dst, round);
                    let want = (qa + qb).clamp(dst.min_raw(), dst.max_raw());
                    let got = add_raw(a, af, b, bf, dst, round);
                    assert_eq!(got, want, "{a}+{b} ({af}+{bf}→{dst}, {})", round.name());
                    let fx = fx_add(Fx::from_raw(a, af), Fx::from_raw(b, bf), dst, round);
                    assert_eq!(got, fx.raw());
                }
            }
        }
    }
}

#[test]
fn graph_requant_bit_exact_on_full_grids() {
    // Every raw word of each source format through every destination
    // and mode: covers exact widening (all modes agree), narrowing
    // ties (rem == half hits every 2^(sh-1)-th word), and saturation
    // (S3.12's ±6+ range into S.7's ±1).
    use tanh_vlsi::graph::ops::requant_raw;
    let pairs = [
        (QFormat::S3_12, QFormat::S_7),
        (QFormat::S_7, QFormat::S3_12),
        (QFormat::S_15, QFormat::S2_5),
        (QFormat::S2_5, QFormat::S_15),
        (QFormat::S2_13, QFormat::S2_13),
    ];
    for (src, dst) in pairs {
        for round in ROUNDS {
            for v in src.min_raw()..=src.max_raw() {
                let want = quantize_ref(v as f64 * src.ulp(), dst, round);
                let got = requant_raw(v, src, dst, round);
                assert_eq!(got, want, "raw {v} ({src}→{dst}, {})", round.name());
                let fx = Fx::from_raw(v, src).convert(dst, round);
                assert_eq!(got, fx.raw());
            }
        }
    }
}

#[test]
fn graph_one_minus_bit_exact_on_full_grids() {
    // 1 − x runs exact in a widened intermediate, then one rounding:
    // the reference is a single quantization of the exact complement.
    // Includes x = min_raw (complement ≈ +2, needs the wide form) and
    // the saturating fraction-only destinations.
    use tanh_vlsi::graph::ops::one_minus_raw;
    for src in [QFormat::S_7, QFormat::S2_5] {
        for dst in [QFormat::S_7, QFormat::S2_5, QFormat::S3_12] {
            for round in ROUNDS {
                for v in src.min_raw()..=src.max_raw() {
                    let want = quantize_ref(1.0 - v as f64 * src.ulp(), dst, round);
                    let got = one_minus_raw(v, src, dst, round);
                    assert_eq!(got, want, "1 − raw {v} ({src}→{dst}, {})", round.name());
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cell-graph rewrites: the fused (shared-tanh-kernel) LSTM graph is
// bit-identical to the unfused reference semantics.
// ---------------------------------------------------------------------------

#[test]
fn fused_lstm_graph_is_bit_identical_and_shares_registry_kernels() {
    use tanh_vlsi::graph::{
        execute_raw, lstm_cell, optimize, BackendSink, CellConfig, FreshKernelSink,
    };
    let cfg = CellConfig::table1_lstm();
    let unfused = lstm_cell(&cfg).unwrap();
    let (fused, stats) = optimize(&unfused).unwrap();
    assert_eq!(stats.fused_sigmoids, 3);

    prop_check("fused == unfused bit-for-bit", 20, |g: &mut Prng| {
        let lanes = g.i64_in(1, 64) as usize;
        let inputs: Vec<(&str, Vec<i64>)> = unfused
            .inputs()
            .into_iter()
            .map(|(name, _, fmt)| {
                let range = if name == "c_prev" { 1.9 } else { 6.0 };
                let vals = (0..lanes)
                    .map(|_| Fx::from_f64(g.f64_in(-range, range), fmt).raw())
                    .collect();
                (name, vals)
            })
            .collect();
        // Unfused: fresh scalar sigmoid wrappers + private kernels.
        let a = execute_raw(&unfused, &inputs, &FreshKernelSink::for_graph(&unfused))?;
        // Fused: everything through the registry-backed golden backend.
        let backend = GoldenBackend::new();
        let b = execute_raw(&fused, &inputs, &BackendSink::new(&backend))?;
        if a != b {
            return Err(format!("fused run diverged on {lanes} lanes"));
        }
        Ok(())
    });

    // The fusion's whole point: the derived sigmoid tanh spec is served
    // from the shared registry like any other spec — one compile, hits
    // after (exercised again via a second backend over the same specs).
    let reg = tanh_vlsi::approx::Registry::global();
    let before = reg.stats();
    for spec in fused.activation_specs() {
        reg.kernel(&spec);
        reg.kernel(&spec);
    }
    let after = reg.stats();
    assert!(after.hits >= before.hits + fused.activation_specs().len() as u64);
}
