//! Golden regression fixtures for the paper-table renderers.
//!
//! The Table I/II/III reports are deterministic (exhaustive sweeps over
//! fixed grids, fixed formatting), so their rendered text is pinned
//! under `tests/fixtures/` and diffed exactly — report drift (a
//! formatting tweak, a numerics change, an accidental reordering) fails
//! here instead of needing eyeballs.
//!
//! Workflow:
//! - normal run: compare byte-for-byte against the checked-in fixture;
//! - fixture missing (fresh platform): write it and pass with a notice
//!   (commit the generated file);
//! - intentional change: rerun with `TANH_UPDATE_FIXTURES=1` to accept,
//!   then review the fixture diff in the PR.

use std::path::PathBuf;

use tanh_vlsi::approx::velocity::Velocity;
use tanh_vlsi::error::Table3Spec;
use tanh_vlsi::fixed::QFormat;
use tanh_vlsi::report;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name)
}

fn check_fixture(name: &str, actual: &str) {
    let path = fixture_path(name);
    let update = std::env::var("TANH_UPDATE_FIXTURES").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!(
            "report_fixtures: wrote {} ({} bytes){}",
            path.display(),
            actual.len(),
            if update { "" } else { " — seeded missing fixture; commit it" }
        );
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap();
    if expected == actual {
        return;
    }
    for (i, (e, a)) in expected.lines().zip(actual.lines()).enumerate() {
        assert_eq!(
            a,
            e,
            "{name} drifted at line {} (TANH_UPDATE_FIXTURES=1 to accept an intended change)",
            i + 1
        );
    }
    panic!(
        "{name} drifted: {} vs {} lines (TANH_UPDATE_FIXTURES=1 to accept an intended change)",
        actual.lines().count(),
        expected.lines().count()
    );
}

#[test]
fn table1_report_matches_fixture() {
    // Full exhaustive Table I sweep — deterministic in grid, kernels and
    // formatting.
    check_fixture("table1.txt", &report::table1::render(&report::table1::compute()));
}

#[test]
fn table2_report_matches_fixture() {
    check_fixture("table2.txt", &report::table2::render(&Velocity::table1()));
}

#[test]
fn table3_row4_report_matches_fixture() {
    // The cheap 8-bit row (S2.5 → S.7 ±4) — the full table is a bench,
    // not a unit test; one row pins the search plus the renderer.
    let spec = Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 };
    let row = report::table3::compute_table3_row(spec, 1.0);
    check_fixture("table3_row4.txt", &report::table3::render(&[row]));
}
