//! RTL netlist equivalence chain: the elaborated cell graph must match
//! the cycle-accurate hw pipeline and the compiled golden kernel
//! bit-exact on raw fixed-point words — over the *complete* Table I
//! input grids, plus variant (non-Table-I) and seeded random design
//! points — and the emitted Verilog must re-parse into a structurally
//! identical netlist.

use tanh_vlsi::approx::{IoSpec, MethodParams, MethodSpec, TanhApprox};
use tanh_vlsi::backend::{CostProbe, CostSource, ErrorCode};
use tanh_vlsi::explore::explore_specs_probed;
use tanh_vlsi::fixed::Fx;
use tanh_vlsi::hw::pipeline_for;
use tanh_vlsi::rtl::{elaborate, eval_flush, simulate, verilog, NetlistProbe};
use tanh_vlsi::util::proptest::{prop_check, Prng};

/// Non-Table-I design points the hw lowering supports — same variants
/// the hw backend's own tests exercise.
const VARIANT_SPECS: [&str; 6] = [
    "pwl:step=1/32:in=s2.13:out=s.15",
    "taylor1:step=1/32",
    "taylor2:step=1/16:out=s.7",
    "catmull:step=1/8:dom=4",
    "velocity:threshold=1/64",
    "lambert:terms=9",
];

/// Asserts netlist == golden kernel on every `stride`-th raw input,
/// and netlist == hw pipeline on a coarser sub-stride.
fn assert_chain(spec: &MethodSpec, stride: i64) {
    let design = elaborate(spec).unwrap_or_else(|e| panic!("elaborate '{spec}': {e}"));
    let kernel = spec.build().compile(spec.io);
    let pipe = pipeline_for(spec).expect("supported spec lowers");
    assert_eq!(design.stages as usize, pipe.latency(), "{spec}");
    let (lo, hi) = (spec.io.input.min_raw(), spec.io.input.max_raw());
    let mut x = lo;
    let mut n = 0u64;
    while x <= hi {
        let got = eval_flush(&design, x);
        let want = kernel.eval_raw(x);
        assert_eq!(
            got, want,
            "{spec}: netlist {got} != golden {want} at raw {x}"
        );
        // The pipeline side of the chain on a coarser sub-stride (its
        // equality with the kernel is already pinned exhaustively by
        // the hw backend's own audit tests).
        if n % 17 == 0 {
            let pw = pipe.eval(Fx::from_raw(x, spec.io.input)).raw();
            assert_eq!(got, pw, "{spec}: netlist {got} != pipeline {pw} at raw {x}");
        }
        n += 1;
        x += stride;
    }
}

#[test]
fn netlist_matches_kernel_and_pipeline_on_full_table1_grids() {
    // The tentpole invariant: every raw input word of every Table I
    // spec, netlist == golden kernel (stride 1 = complete grid).
    for spec in MethodSpec::table1_all() {
        assert_chain(&spec, 1);
    }
}

#[test]
fn variant_specs_stay_bit_exact() {
    for s in VARIANT_SPECS {
        let spec = MethodSpec::parse(s).unwrap_or_else(|e| panic!("'{s}': {e}"));
        assert_chain(&spec, 3);
    }
}

#[test]
fn seeded_random_specs_stay_bit_exact() {
    // Randomized non-Table-I points: domains deliberately != 6.0 so no
    // draw collides with a Table I row.
    let domains = [4.0, 5.0, 8.0];
    prop_check("netlist == kernel on random specs", 8, |g: &mut Prng| {
        let domain = *g.choose(&domains);
        let spec = match g.i64_in(0, 5) {
            0 => format!("pwl:step=1/{}:dom={domain}", 1 << g.i64_in(3, 7)),
            1 => format!("taylor1:step=1/{}:dom={domain}", 1 << g.i64_in(3, 6)),
            2 => format!("taylor2:step=1/{}:dom={domain}", 1 << g.i64_in(3, 6)),
            3 => format!("catmull:step=1/{}:dom={domain}", 1 << g.i64_in(3, 6)),
            4 => format!("velocity:threshold=1/{}:dom={domain}", 1 << g.i64_in(4, 8)),
            _ => format!("lambert:terms={}:dom={domain}", g.i64_in(1, 16)),
        };
        let spec = MethodSpec::parse(&spec).map_err(|e| format!("'{spec}': {e}"))?;
        let design = elaborate(&spec).map_err(|e| format!("elaborate '{spec}': {e}"))?;
        let kernel = spec.build().compile(spec.io);
        let (lo, hi) = (spec.io.input.min_raw(), spec.io.input.max_raw());
        let mut x = lo;
        while x <= hi {
            let got = eval_flush(&design, x);
            let want = kernel.eval_raw(x);
            if got != want {
                return Err(format!("{spec}: netlist {got} != golden {want} at raw {x}"));
            }
            x += 89;
        }
        Ok(())
    });
}

#[test]
fn clocked_simulation_matches_flush_on_the_pipelined_schedule() {
    for spec in MethodSpec::table1_all() {
        let design = elaborate(&spec).unwrap();
        let (lo, hi) = (spec.io.input.min_raw(), spec.io.input.max_raw());
        let xs: Vec<i64> = (lo..=hi).step_by(257).collect();
        let (ys, cycles) = simulate(&design, &xs);
        assert_eq!(ys.len(), xs.len(), "{spec}");
        // Fully pipelined: one result per cycle after the fill.
        assert_eq!(cycles, design.stages as u64 + xs.len() as u64 - 1, "{spec}");
        for (&x, &y) in xs.iter().zip(&ys) {
            assert_eq!(y, eval_flush(&design, x), "{spec}: clocked != flush at raw {x}");
        }
    }
}

#[test]
fn verilog_round_trips_variant_netlists() {
    // The Table I six round-trip in hw::verilog's own tests; variants
    // cover the remaining datapath shapes (different widths, domains,
    // register counts).
    for s in VARIANT_SPECS {
        let spec = MethodSpec::parse(s).unwrap();
        let design = elaborate(&spec).unwrap();
        let v = verilog::emit(&design);
        let back = verilog::parse(&v).unwrap_or_else(|e| panic!("'{s}': {e}"));
        assert_eq!(back, design, "'{s}': emission drifted from the netlist");
    }
}

#[test]
fn unsupported_specs_error_typed_never_elaborate() {
    // Structurally bogus points (constructed directly — MethodSpec::new
    // would already reject them) must fail with the hw backend's typed
    // wording, not panic or emit garbage.
    let cases: [(MethodParams, &str); 3] = [
        (MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 }, "Horner"),
        (MethodParams::Pwl { step: 0.3 }, "reciprocal power of two"),
        (MethodParams::Lambert { terms: 40 }, "1..=16"),
    ];
    for (params, needle) in cases {
        let bogus = MethodSpec { params, io: IoSpec::table1(), domain: 6.0 };
        let err = elaborate(&bogus).unwrap_err();
        assert!(err.contains("unsupported by hw backend"), "{err}");
        assert!(err.contains(needle), "'{err}' missing '{needle}'");
    }
}

#[test]
fn explore_rows_carry_the_netlist_cost_tier() {
    let probe = NetlistProbe::new();
    let specs = MethodSpec::table1_all();
    let points = explore_specs_probed(&specs, 64, &probe).expect("probing succeeds");
    assert_eq!(points.len(), specs.len());
    for pt in &points {
        assert_eq!(pt.cost_source, CostSource::Netlist, "{}", pt.spec);
        assert!(pt.area_ge > 0.0, "{}: zero netlist area", pt.spec);
        assert!(pt.stage_delay_fo4 > 0.0, "{}: zero critical path", pt.spec);
        assert!(pt.latency_cycles > 0, "{}", pt.spec);
    }
}

#[test]
fn probe_errors_are_typed_for_the_analytic_fallback() {
    // The explorer's labeled-fallback contract hinges on the probe
    // answering `unknown_spec` (not `internal`) for unsupported points.
    let probe = NetlistProbe::new();
    let bogus = MethodSpec {
        params: MethodParams::Velocity { threshold: 0.3 },
        io: IoSpec::table1(),
        domain: 6.0,
    };
    let err = probe.probe_cost(&bogus).unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownSpec);
}
