//! Golden Verilog snapshots for the six Table I datapaths.
//!
//! The emitted RTL is fully deterministic (elaboration walks the same
//! golden configuration objects in a fixed order, the printer is
//! canonical), so the complete emission of each Table I spec is pinned
//! under `tests/fixtures/rtl/` and byte-diffed — an elaboration or
//! printer change that alters any cell, net or ROM entry fails here
//! instead of needing eyeballs over thousands of lines of Verilog.
//!
//! Same protocol as the report fixtures: a missing fixture is seeded
//! and reported (commit it); an intentional change is accepted with
//! `TANH_UPDATE_FIXTURES=1` and reviewed as a fixture diff in the PR.

use std::path::PathBuf;

use tanh_vlsi::approx::MethodSpec;
use tanh_vlsi::rtl::{elaborate, verilog};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("rtl")
        .join(name)
}

fn check_fixture(name: &str, actual: &str) {
    let path = fixture_path(name);
    let update = std::env::var("TANH_UPDATE_FIXTURES").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!(
            "rtl_fixtures: wrote {} ({} bytes){}",
            path.display(),
            actual.len(),
            if update { "" } else { " — seeded missing fixture; commit it" }
        );
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap();
    if expected == actual {
        return;
    }
    for (i, (e, a)) in expected.lines().zip(actual.lines()).enumerate() {
        assert_eq!(
            a,
            e,
            "{name} drifted at line {} (TANH_UPDATE_FIXTURES=1 to accept an intended change)",
            i + 1
        );
    }
    panic!(
        "{name} drifted: {} vs {} lines (TANH_UPDATE_FIXTURES=1 to accept an intended change)",
        actual.lines().count(),
        expected.lines().count()
    );
}

/// Fixture file name for one Table I row, derived from the lowered
/// pipeline name (e.g. `pwl/fig3` → `table1_pwl.v`).
fn fixture_name(design_name: &str) -> String {
    let method = design_name.split('/').next().unwrap_or(design_name);
    format!("table1_{}.v", method.replace('-', "_"))
}

#[test]
fn table1_rtl_emissions_match_fixtures() {
    for spec in MethodSpec::table1_all() {
        let design = elaborate(&spec).expect("Table I specs elaborate");
        let v = verilog::emit(&design);
        // The snapshot must itself round-trip before it is pinned.
        let back = verilog::parse(&v).expect("own emission parses");
        assert_eq!(back, design, "{spec}: emission drifted from the netlist");
        check_fixture(&fixture_name(&design.name), &v);
    }
}
