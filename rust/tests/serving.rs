//! Serving-stack integration tests: stress/soak over the sharded
//! coordinator (backpressure, drain-on-shutdown, metrics conservation)
//! and the deterministic scenario harness (same seed ⇒ same workload ⇒
//! same completion counts, every reply bit-exact vs the compiled
//! golden kernels) — plus the cross-backend properties of the unified
//! execution layer: the same scenario trace served on `golden` and
//! `hw` produces bit-identical replies, and `hw` runs report simulated
//! cycle counts.

use std::sync::Arc;
use std::time::Duration;

use tanh_vlsi::approx::{MethodId, MethodSpec};
use tanh_vlsi::backend::{
    Availability, BackendError, ErrorCode, EvalBackend, EvalStats, GoldenBackend, HwBackend,
};
use tanh_vlsi::bench::scenario::{
    build_trace, run_trace, validate_serve_log, RunOptions, Verify, SCENARIO_NAMES,
};
use tanh_vlsi::bench::sockets::{run_trace_sockets, Framing, SocketRunOptions};
use tanh_vlsi::bench::BenchLog;
use tanh_vlsi::coordinator::{
    BinClient, Coordinator, CoordinatorConfig, MetricsSnapshot, NetClient, NetServer,
    RoutePolicy,
};

fn table1() -> Vec<MethodSpec> {
    MethodSpec::table1_all()
}

/// A deliberately slow backend so queues actually fill.
struct SlowBackend {
    inner: GoldenBackend,
    delay: Duration,
}

impl SlowBackend {
    fn new(delay: Duration) -> SlowBackend {
        SlowBackend { inner: GoldenBackend::new(), delay }
    }
}

impl EvalBackend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow-golden"
    }
    fn availability(&self) -> Availability {
        self.inner.availability()
    }
    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        self.inner.ensure(spec)
    }
    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.eval_raw(spec, input, out)
    }
}

#[test]
fn stress_backpressure_fails_fast_and_metrics_conserve_across_shards() {
    let mut cfg = CoordinatorConfig::with_batch(64);
    cfg.batcher.max_queue = 128;
    cfg.shards = 2;
    cfg.route = RoutePolicy::LeastLoaded;
    let coord = Arc::new(
        Coordinator::start(Arc::new(SlowBackend::new(Duration::from_millis(2))), cfg).unwrap(),
    );

    // Concurrent submitters flooding a slow backend: every submit either
    // returns a receiver (accepted) or fails fast with a typed
    // overloaded error — never blocks.
    let mut handles = Vec::new();
    for c in 0..6usize {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let method = MethodId::all()[c];
            let mut accepted = Vec::new();
            let mut rejected = 0u64;
            for i in 0..120 {
                let values = vec![(i as f32) * 0.05 - 3.0; 16];
                match coord.submit(method, values) {
                    Ok(rx) => accepted.push(rx),
                    Err(e) => {
                        assert_eq!(e.code, ErrorCode::Overloaded, "unexpected error: {e}");
                        assert!(e.message.contains("backpressure"), "{e}");
                        rejected += 1;
                    }
                }
            }
            // Every accepted request still completes (drain).
            let mut completed = 0u64;
            let mut failed = 0u64;
            for rx in accepted {
                match rx.recv().expect("reply delivered").outcome {
                    Ok(out) => {
                        assert_eq!(out.len(), 16);
                        completed += 1;
                    }
                    Err(_) => failed += 1,
                }
            }
            (completed, failed, rejected)
        }));
    }
    let mut total_completed = 0u64;
    let mut total_failed = 0u64;
    let mut total_rejected = 0u64;
    for h in handles {
        let (c, f, r) = h.join().unwrap();
        total_completed += c;
        total_failed += f;
        total_rejected += r;
    }
    assert!(total_rejected > 0, "backpressure never engaged under a 2ms/batch backend");
    assert!(total_completed > 0, "nothing completed");

    // Conservation, per shard and merged: every accepted request is
    // accounted as completed or failed; every attempt as accepted or
    // rejected; every failure as backend- or admission-kinded.
    let merged = coord.metrics();
    assert_eq!(merged.submitted, total_completed + total_failed);
    assert_eq!(merged.requests, total_completed);
    assert_eq!(merged.failed_requests, total_failed);
    assert_eq!(
        merged.failed_requests,
        merged.backend_failed_requests + merged.admission_failed_requests
    );
    assert_eq!(merged.rejected, total_rejected);
    assert_eq!(merged.submitted + merged.rejected, 6 * 120);
    let mut fold = MetricsSnapshot::default();
    for (_, _, shard) in coord.shard_metrics() {
        assert_eq!(
            shard.submitted,
            shard.requests + shard.failed_requests,
            "per-shard conservation violated"
        );
        fold = fold.merge(&shard);
    }
    // Kernel-cache counters are process-global (injected by metrics(),
    // not folded from shards); align them before the exact comparison.
    fold.kernel_cache_hits = merged.kernel_cache_hits;
    fold.kernel_compiles = merged.kernel_compiles;
    assert_eq!(fold, merged, "merged metrics must equal the fold of shard metrics");

    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn shutdown_drains_in_flight_batches() {
    let coord = Coordinator::start(
        Arc::new(SlowBackend::new(Duration::from_millis(1))),
        CoordinatorConfig::with_batch(64),
    )
    .unwrap();
    // Queue work across all methods, then shut down immediately: the
    // disconnect path must flush queued + partial batches, so every
    // reply still arrives.
    let mut receivers = Vec::new();
    for i in 0..36 {
        let method = MethodId::all()[i % 6];
        receivers.push((i, coord.submit(method, vec![0.25; 8]).unwrap()));
    }
    coord.shutdown();
    for (i, rx) in receivers {
        let result = rx.recv().unwrap_or_else(|_| panic!("reply {i} dropped on shutdown"));
        let out = result.outcome.unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(out.len(), 8);
    }
}

#[test]
fn scenarios_complete_deterministically_and_verify_bit_exact() {
    // The acceptance property: same (scenario, seed, batch, scale) ⇒
    // identical deterministic fields across independent runs, with
    // every reply verified bit-exact against the compiled golden
    // kernels, on ≥ 2 shards per method.
    let batch = 128;
    let backend = Arc::new(GoldenBackend::new());
    let opts = RunOptions { verify: Verify::Exact, ..Default::default() };
    let mut log = BenchLog::new();
    for name in SCENARIO_NAMES {
        let trace = build_trace(name, 42, batch, 0.05, &table1()).unwrap();
        let mut fields = Vec::new();
        for _run in 0..2 {
            let coord = Coordinator::start(
                backend.clone(),
                CoordinatorConfig { shards: 2, ..CoordinatorConfig::with_batch(batch) },
            )
            .unwrap();
            assert!(coord.shards_per_method() >= 2);
            let out = run_trace(&coord, &trace, &opts)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(out.submitted as usize, trace.requests.len(), "{name}");
            assert_eq!(out.completed, out.submitted, "{name}: requests went missing");
            assert_eq!(out.failed, 0, "{name}");
            assert_eq!(out.verified, out.completed, "{name}: unverified replies");
            assert_eq!(out.elements, trace.total_elements(), "{name}");
            // Table I specs all qualify for the SWAR lanes, so every
            // executed batch on the golden backend is a packed batch.
            assert!(out.metrics.batches > 0, "{name}");
            assert_eq!(
                out.metrics.packed_batches, out.metrics.batches,
                "{name}: golden Table I serving must run packed"
            );
            fields.push(out.deterministic_fields().to_string_pretty());
            if fields.len() == 2 {
                log.push_row(out.to_json("golden", coord.shards_per_method(), batch));
            }
            coord.shutdown();
        }
        assert_eq!(fields[0], fields[1], "{name}: deterministic fields drifted between runs");
    }
    // The collected rows form a schema-valid BENCH_serve.json.
    assert_eq!(validate_serve_log(&log.to_json()).unwrap(), SCENARIO_NAMES.len());
}

#[test]
fn hw_backend_serves_scenarios_bit_exact_with_cycle_counts() {
    // The multi-backend acceptance criterion, end to end: a steady
    // scenario served on the cycle-accurate hw backend completes with
    // every reply verified BIT-EXACT against independently compiled
    // golden kernels (Verify::Exact — the verifier knows nothing about
    // the backend), and the metrics carry the simulated-hardware
    // latency column.
    let batch = 128;
    let specs = table1();
    let trace = build_trace("steady", 42, batch, 0.05, &specs).unwrap();
    let coord = Coordinator::start(
        Arc::new(HwBackend::new()),
        CoordinatorConfig { shards: 2, ..CoordinatorConfig::with_batch(batch) },
    )
    .unwrap();
    assert_eq!(coord.backend_name(), "hw");
    let opts = RunOptions { verify: Verify::Exact, ..Default::default() };
    let out = run_trace(&coord, &trace, &opts).unwrap();
    assert_eq!(out.completed as usize, trace.requests.len());
    assert_eq!(out.verified, out.completed, "unverified replies");
    assert_eq!(out.failed, 0);
    assert!(out.metrics.sim_cycles > 0, "hw serving must report simulated cycles");
    // The packed-batch counter is a golden-kernel observable; the hw
    // datapath never reports it.
    assert_eq!(out.metrics.packed_batches, 0, "hw serving must not count packed batches");
    // The BENCH_serve.json row carries both the backend name and the
    // cycle column.
    let row = out.to_json("hw", coord.shards_per_method(), batch);
    let text = row.to_string_compact();
    assert!(text.contains("\"backend\":\"hw\""), "{text}");
    assert!(text.contains("\"sim_cycles\":"), "{text}");
    coord.shutdown();
}

#[test]
fn same_trace_on_golden_and_hw_yields_identical_reply_bytes() {
    // Cross-backend determinism: replaying the same trace request-by-
    // request against a golden-backed and an hw-backed coordinator
    // must produce byte-identical outputs for every reply (both paths
    // are bit-exact realizations of the same specs), and both runs'
    // deterministic outcome fields must match.
    let batch = 64;
    let specs = table1();
    let trace = build_trace("zipf", 9, batch, 0.03, &specs).unwrap();
    let cfg = CoordinatorConfig { shards: 2, ..CoordinatorConfig::with_batch(batch) };
    let golden = Coordinator::start(Arc::new(GoldenBackend::new()), cfg.clone()).unwrap();
    let hw = Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap();
    for (i, req) in trace.requests.iter().enumerate() {
        let a = golden.evaluate_spec(&req.spec, req.values.clone()).unwrap();
        let b = hw.evaluate_spec(&req.spec, req.values.clone()).unwrap();
        let a_bytes: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
        let b_bytes: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a_bytes, b_bytes, "request {i} ({}) diverged between backends", req.spec);
    }
    // And the full harness agrees: run_trace outcomes match on the
    // deterministic fields.
    let opts = RunOptions { verify: Verify::Exact, ..Default::default() };
    let out_g = run_trace(&golden, &trace, &opts).unwrap();
    let out_h = run_trace(&hw, &trace, &opts).unwrap();
    assert_eq!(
        out_g.deterministic_fields().to_string_pretty(),
        out_h.deterministic_fields().to_string_pretty()
    );
    golden.shutdown();
    hw.shutdown();
}

#[test]
fn non_table1_spec_serves_bit_exact_against_fresh_golden_kernel() {
    // The acceptance criterion for the spec redesign: a design point
    // the old API could not even name (PWL at step 1/32 with an S2.13
    // input) runs through a 2-shard coordinator scenario with every
    // reply verified bit-exact — the verifier fresh-compiles its
    // kernel, independent of the serving backend's cached one.
    let batch = 128;
    let spec = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
    assert_ne!(spec, MethodSpec::table1(MethodId::Pwl));
    let specs = vec![spec];
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig {
            shards: 2,
            specs: specs.clone(),
            ..CoordinatorConfig::with_batch(batch)
        },
    )
    .unwrap();
    assert!(coord.shards_per_method() >= 2);
    let trace = build_trace("steady", 7, batch, 0.05, &specs).unwrap();
    let opts = RunOptions { verify: Verify::Exact, ..Default::default() };
    let out = run_trace(&coord, &trace, &opts).unwrap();
    assert_eq!(out.completed as usize, trace.requests.len());
    assert_eq!(out.verified, out.completed, "unverified replies");
    assert_eq!(out.failed, 0);
    assert_eq!(out.specs, vec![spec.to_string()]);
    // The report row carries the spec string, so BENCH_serve.json
    // readers can reproduce the run with --spec.
    let row = out.to_json("golden", coord.shards_per_method(), batch);
    let text = row.to_string_compact();
    assert!(text.contains("pwl:step=1/32:in=S2.13:out=S.15"), "{text}");
    coord.shutdown();
}

#[test]
fn mixed_table1_and_custom_specs_serve_together() {
    // One coordinator, seven design points: the six Table I rows plus
    // a custom one — the zipf mix spreads over all seven and every
    // reply still verifies bit-exact.
    let batch = 128;
    let mut specs = table1();
    specs.push(MethodSpec::parse("lambert:terms=9").unwrap());
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig {
            shards: 2,
            specs: specs.clone(),
            ..CoordinatorConfig::with_batch(batch)
        },
    )
    .unwrap();
    let trace = build_trace("zipf", 13, batch, 0.1, &specs).unwrap();
    let out = run_trace(&coord, &trace, &RunOptions::default()).unwrap();
    assert_eq!(out.failed, 0);
    assert_eq!(out.verified, out.completed);
    assert_eq!(out.specs.len(), 7);
    coord.shutdown();
}

#[test]
fn paced_replay_honors_the_open_loop_schedule() {
    // The steady trace spans (count-1) * 30 µs of schedule; a paced run
    // cannot finish faster than the schedule's span.
    let batch = 128;
    let trace = build_trace("steady", 7, batch, 0.05, &table1()).unwrap();
    let span_us = trace.requests.last().unwrap().at_us;
    assert!(span_us > 0);
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig::with_batch(batch),
    )
    .unwrap();
    let opts = RunOptions { pace: true, verify: Verify::Exact, ..Default::default() };
    let out = run_trace(&coord, &trace, &opts).unwrap();
    assert!(
        out.wall >= Duration::from_micros(span_us),
        "paced run finished in {:?}, before the {span_us} µs schedule end",
        out.wall
    );
    assert_eq!(out.failed, 0);
    coord.shutdown();
}

#[test]
fn flood_scenario_spreads_load_across_shards() {
    // Round-robin routing must actually use the pool: after a flood,
    // more than one shard of a flooded method has accepted traffic.
    let batch = 128;
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig { shards: 3, ..CoordinatorConfig::with_batch(batch) },
    )
    .unwrap();
    let trace = build_trace("flood", 11, batch, 0.1, &table1()).unwrap();
    let out = run_trace(&coord, &trace, &RunOptions::default()).unwrap();
    assert_eq!(out.failed, 0);
    let pwl_busy = coord
        .shard_metrics()
        .into_iter()
        .filter(|(s, _, m)| s.method_id() == MethodId::Pwl && m.submitted > 0)
        .count();
    assert!(pwl_busy >= 2, "flood used only {pwl_busy} of 3 PWL shards");
    // Merged latency histogram saw every reply.
    let merged = coord.metrics();
    assert_eq!(merged.latency.count, merged.requests + merged.failed_requests);
    coord.shutdown();
}

#[test]
fn socket_soak_dozens_of_mixed_framing_connections_stay_bit_exact() {
    // The concurrency soak for the nonblocking front-end: a zipf trace
    // split over 24 simultaneous TCP connections — half JSON lines,
    // half binary frames, each pipelining up to a 16-request window —
    // with every reply verified bit-exact against freshly compiled
    // golden kernels, and the coordinator's conservation laws exact
    // after the run.
    let batch = 128;
    let specs = table1();
    let coord = Arc::new(
        Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig { shards: 2, ..CoordinatorConfig::with_batch(batch) },
        )
        .unwrap(),
    );
    let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let trace = build_trace("zipf", 23, batch, 0.2, &specs).unwrap();
    assert!(trace.requests.len() >= 100, "soak needs real volume");
    let opts = SocketRunOptions {
        connections: 24,
        framing: Framing::Mixed,
        verify: Verify::Exact,
        window: 16,
        pace: false,
    };
    let out = run_trace_sockets(&coord, &server, &trace, &opts).unwrap();
    assert_eq!(out.submitted as usize, trace.requests.len());
    assert_eq!(out.completed, out.submitted, "requests went missing over the sockets");
    assert_eq!(out.failed, 0);
    assert_eq!(out.verified, out.completed, "unverified replies");
    assert_eq!(out.elements, trace.total_elements());
    // Net observables are real: all 24 connections open at snapshot
    // time, traffic both ways, one round-trip sample per request.
    let net = out.net.as_ref().expect("socket replay must carry net observables");
    assert_eq!(net.connections, 24);
    assert!(net.accepted_conns >= 24, "{net:?}");
    assert_eq!(net.active_conns, 24, "{net:?}");
    assert!(net.bytes_in > 0 && net.bytes_out > 0, "{net:?}");
    assert_eq!(net.conn_latency.count, out.completed);
    // Conservation through the wire: everything the sockets pushed is
    // accounted in the coordinator's merged metrics.
    let m = &out.metrics;
    assert_eq!(m.submitted, out.submitted);
    assert_eq!(m.requests, out.completed);
    assert_eq!(m.failed_requests, 0);
    assert_eq!(m.submitted, m.requests + m.failed_requests);
    // The report row validates against the serve-log schema, socket
    // columns included.
    let mut log = BenchLog::new();
    log.push_row(out.to_json("golden", 2, batch));
    assert_eq!(validate_serve_log(&log.to_json()).unwrap(), 1);
    server.stop();
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn all_binary_socket_replay_matches_the_coordinator_counters() {
    // All-binary framing over 8 connections: raw i64 words in, raw
    // words out, zero per-request serde — still verified bit-exact
    // (raw-word equality) against the golden kernels.
    let batch = 128;
    let specs = table1();
    let coord = Arc::new(
        Coordinator::start(Arc::new(GoldenBackend::new()), CoordinatorConfig::with_batch(batch))
            .unwrap(),
    );
    let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let trace = build_trace("bursty", 5, batch, 0.1, &specs).unwrap();
    let opts = SocketRunOptions {
        connections: 8,
        framing: Framing::Binary,
        ..SocketRunOptions::default()
    };
    let out = run_trace_sockets(&coord, &server, &trace, &opts).unwrap();
    assert_eq!(out.completed as usize, trace.requests.len());
    assert_eq!(out.failed, 0);
    assert_eq!(out.verified, out.completed);
    assert_eq!(out.net.as_ref().unwrap().framing, "binary");
    assert_eq!(out.metrics.requests, out.completed);
    server.stop();
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn server_stops_cleanly_with_connections_open_and_coordinator_survives() {
    // Clean shutdown under load: stop() must join the event loop while
    // clients (both framings) still hold open connections; the clients
    // observe EOF, and the coordinator keeps serving afterwards.
    let coord = Arc::new(
        Coordinator::start(Arc::new(GoldenBackend::new()), CoordinatorConfig::with_batch(64))
            .unwrap(),
    );
    let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut json_clients: Vec<NetClient> = (0..6)
        .map(|_| {
            let mut c = NetClient::connect(addr).unwrap();
            assert_eq!(c.evaluate("pwl", &[0.5]).unwrap().len(), 1);
            c
        })
        .collect();
    let spec = coord.specs()[0];
    let raw = tanh_vlsi::fixed::Fx::from_f64(0.5, spec.io.input).raw();
    let mut bin_clients: Vec<BinClient> = (0..2)
        .map(|_| {
            let mut c = BinClient::connect(addr).unwrap();
            assert_eq!(c.evaluate_raw(0, &[raw]).unwrap().len(), 1);
            c
        })
        .collect();
    // Stop with all 8 connections open. This must not hang.
    server.stop();
    // Every open client sees the connection close, not a stuck read.
    use tanh_vlsi::util::json::Json;
    for c in json_clients.iter_mut() {
        let err = c
            .call(&Json::obj(vec![("cmd", Json::s("ping"))]))
            .unwrap_err();
        assert!(
            err.contains("closed") || err.to_lowercase().contains("reset")
                || err.to_lowercase().contains("pipe"),
            "unexpected post-stop error: {err}"
        );
    }
    for c in bin_clients.iter_mut() {
        assert!(c.evaluate_raw(0, &[raw]).is_err());
    }
    // The coordinator outlives its front-end.
    let out = coord.evaluate(MethodId::Pwl, vec![0.25]).unwrap();
    assert_eq!(out.len(), 1);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn lstm_cell_steps_serve_end_to_end_with_schema_valid_bench_rows() {
    // The cell-graph acceptance criterion, end to end: whole LSTM cell
    // steps served through a 2-shard coordinator with the rewrite
    // passes applied (sigmoid gates fused onto shared tanh kernels),
    // every step bit-exact against a direct golden execution and every
    // gate within the declared error budget of the f64 reference — and
    // the resulting BENCH_serve.json row (cell columns included)
    // validates against the schema.
    use tanh_vlsi::bench::scenario::{CellStats, ScenarioOutcome};
    use tanh_vlsi::graph::{lstm_cell, optimize, run_lstm_cells, CellConfig, CellRunConfig};
    use tanh_vlsi::util::json::Json;

    let cfg = CellConfig::table1_lstm();
    let (fused, rw) = optimize(&lstm_cell(&cfg).unwrap()).unwrap();
    assert_eq!(rw.fused_sigmoids, 3, "all three sigmoid gates must fuse");
    let batch = 256;
    let coord = Coordinator::start(
        Arc::new(GoldenBackend::new()),
        CoordinatorConfig {
            shards: 2,
            specs: fused.activation_specs(),
            ..CoordinatorConfig::with_batch(batch)
        },
    )
    .unwrap();
    assert!(coord.shards_per_method() >= 2);

    let run = CellRunConfig { sequences: 3, steps: 4, lanes: 32, seed: 0xBEEF };
    let start = std::time::Instant::now();
    let stats = run_lstm_cells(&coord, &cfg, &fused, &run).unwrap();
    let wall = start.elapsed();
    assert_eq!(stats.cell_steps, 12);
    assert_eq!(stats.verified, 12, "every step double-verified");
    assert!(
        stats.gate_max_err > 0.0 && stats.gate_max_err <= cfg.budget,
        "gate_max_err {} outside (0, {}]",
        stats.gate_max_err,
        cfg.budget
    );
    // 5 activation nodes per step: three fused sigmoid tanh evals, the
    // g gate tanh, and tanh(c_next).
    assert_eq!(stats.requests, 12 * 5);
    assert_eq!(stats.elements, 12 * 5 * 32);
    // The coordinator really served that traffic.
    let m = coord.metrics();
    assert_eq!(m.requests, stats.requests);
    assert_eq!(m.failed_requests, 0);

    let out = ScenarioOutcome {
        name: "lstm".into(),
        seed: run.seed,
        specs: fused.activation_specs().iter().map(|s| s.to_string()).collect(),
        submitted: stats.requests,
        completed: stats.requests,
        failed: 0,
        retries: stats.retries,
        elements: stats.elements,
        verified: stats.requests,
        wall,
        metrics: m,
        net: None,
        cells: Some(CellStats {
            cell_steps: stats.cell_steps,
            gate_max_err: stats.gate_max_err,
        }),
        stream: None,
    };
    let row = out.to_json("golden", coord.shards_per_method(), batch);
    let mut log = BenchLog::new();
    log.push_row(row.clone());
    assert_eq!(validate_serve_log(&log.to_json()).unwrap(), 1);
    let text = row.to_string_compact();
    assert!(text.contains("\"cell_steps\":12"), "{text}");
    assert!(text.contains("\"gate_max_err\":"), "{text}");
    // A cell row claiming steps but a zero error observable is hollow
    // (the reference was never consulted) and must be rejected.
    let mut hollow = out.clone();
    hollow.cells = Some(CellStats { cell_steps: 12, gate_max_err: 0.0 });
    let bad = Json::arr(vec![hollow.to_json("golden", 2, batch)]).to_string_compact();
    assert!(validate_serve_log(&bad).unwrap_err().contains("gate_max_err"));
    coord.shutdown();
}
