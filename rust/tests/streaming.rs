//! Integration tests for the coordinator's streaming-session layer:
//! exact delay accounting on the cycle-accurate hw backend, shard
//! pinning for a session's whole life, idle-timeout eviction and the
//! max-sessions cap, interleaved-session bit-exactness against a cold
//! golden replay, and the headline win — a warm session's steady-state
//! simulated cycles per element beating the per-batch re-fill
//! baseline measured off the same backend.

use std::sync::Arc;
use std::time::Duration;

use tanh_vlsi::approx::{MethodId, MethodSpec};
use tanh_vlsi::backend::{ErrorCode, GoldenBackend, HwBackend};
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig, SessionConfig};
use tanh_vlsi::fixed::Fx;

/// Raw input words for a spec from f64 test points.
fn raws(spec: &MethodSpec, xs: &[f64]) -> Vec<i64> {
    xs.iter().map(|&x| Fx::from_f64(x, spec.io.input).raw()).collect()
}

/// Cold golden replay: the full expected output sequence through a
/// freshly compiled kernel (cache-bypassing).
fn cold(spec: &MethodSpec, input: &[i64]) -> Vec<i64> {
    let kernel = spec.build().compile(spec.io);
    let mut out = vec![0i64; input.len()];
    kernel.eval_slice_raw(input, &mut out);
    out
}

/// Deterministic in-range test points spread over the tanh domain.
fn points(n: usize, phase: usize) -> Vec<f64> {
    (0..n).map(|i| -4.0 + ((i + phase) % 33) as f64 * 0.25).collect()
}

#[test]
fn hw_session_delay_accounting_is_exact() {
    let spec = MethodSpec::table1(MethodId::Pwl);
    let cfg = CoordinatorConfig { specs: vec![spec], ..CoordinatorConfig::with_batch(64) };
    let coord = Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap();
    let info = coord.open_session(&spec).unwrap();
    // The hw stream's advertised delay is the pipeline depth minus one
    // (the first output emerges after `stages` cycles).
    let depth = info.delay as u64 + 1;
    assert!(info.delay > 0, "hw pipelines are staged; delay must be visible");
    let (p, k) = (16usize, 5usize);
    let mut input = Vec::new();
    let mut got = Vec::new();
    let mut cycles = 0u64;
    for i in 0..k {
        let pulse = raws(&spec, &points(p, i * p));
        input.extend_from_slice(&pulse);
        let out = coord.session_pulse_blocking(info.id, pulse).unwrap();
        // The reply lag never exceeds the advertised delay window.
        assert!(out.issued - out.delivered <= info.delay as u64, "{out:?}");
        assert_eq!(out.issued, ((i + 1) * p) as u64);
        cycles += out.sim_cycles;
        got.extend_from_slice(&out.outputs);
    }
    let tail = coord.session_close_blocking(info.id).unwrap();
    // The flush releases already-computed words: zero new cycles, and
    // the ledger balances.
    assert_eq!(tail.sim_cycles, 0, "flush must not re-occupy the datapath");
    assert_eq!(tail.issued, tail.delivered);
    got.extend_from_slice(&tail.outputs);
    // The delay identity: k pulses of P elements through a
    // depth-`stages` pipeline cost exactly stages + k·P − 1 cycles —
    // the fill is paid once per session, not once per pulse.
    assert_eq!(cycles, depth + (k * p) as u64 - 1);
    // And the streamed sequence is bit-exact against the cold replay.
    assert_eq!(got, cold(&spec, &input));
    assert_eq!(coord.sessions_open(), 0);
    coord.shutdown();
}

#[test]
fn sessions_pin_to_one_shard_for_life() {
    let cfg = CoordinatorConfig { shards: 3, ..CoordinatorConfig::with_batch(64) };
    let coord = Coordinator::start(Arc::new(GoldenBackend::new()), cfg).unwrap();
    let specs = coord.specs().to_vec();
    let mut session_shards = Vec::new();
    let mut ids = Vec::new();
    for (i, spec) in specs.iter().take(6).enumerate() {
        let info = coord.open_session(spec).unwrap();
        let mut shard = None;
        for j in 0..8 {
            let out = coord
                .session_pulse_blocking(info.id, raws(spec, &points(4, i + j)))
                .unwrap();
            match shard {
                None => shard = Some(out.shard),
                Some(s) => assert_eq!(s, out.shard, "session {} migrated shards", info.id),
            }
        }
        session_shards.push(shard.unwrap());
        ids.push(info.id);
    }
    // Consecutive session ids spread over the pool (`id % shards`), so
    // streaming load doesn't all pile onto one worker.
    let distinct: std::collections::HashSet<usize> = session_shards.iter().copied().collect();
    assert!(distinct.len() > 1, "6 sessions all landed on one shard: {session_shards:?}");
    for id in ids {
        coord.session_close_blocking(id).unwrap();
    }
    coord.shutdown();
}

#[test]
fn idle_sessions_are_evicted_and_answer_unknown() {
    let cfg = CoordinatorConfig {
        sessions: SessionConfig {
            max_sessions: 4096,
            idle_timeout: Duration::from_millis(40),
        },
        ..CoordinatorConfig::with_batch(64)
    };
    let coord = Coordinator::start(Arc::new(GoldenBackend::new()), cfg).unwrap();
    let spec = coord.specs()[0];
    let info = coord.open_session(&spec).unwrap();
    assert_eq!(coord.sessions_open(), 1);
    std::thread::sleep(Duration::from_millis(80));
    assert_eq!(coord.sweep_sessions(), 1, "idle session must be evicted");
    assert_eq!(coord.sessions_evicted(), 1);
    assert_eq!(coord.sessions_open(), 0);
    // An evicted id answers the same typed error as a never-opened one.
    let err = coord.session_pulse_blocking(info.id, vec![0i64; 4]).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("unknown session"), "{err}");
    // The table still works after eviction: fresh sessions open and
    // stream normally.
    let info2 = coord.open_session(&spec).unwrap();
    assert!(info2.id > info.id);
    let out = coord.session_pulse_blocking(info2.id, raws(&spec, &points(4, 0))).unwrap();
    assert_eq!(out.outputs, cold(&spec, &raws(&spec, &points(4, 0))));
    coord.session_close_blocking(info2.id).unwrap();
    coord.shutdown();
}

#[test]
fn session_table_cap_answers_overloaded() {
    let cfg = CoordinatorConfig {
        sessions: SessionConfig {
            max_sessions: 4,
            idle_timeout: Duration::from_secs(3600),
        },
        ..CoordinatorConfig::with_batch(64)
    };
    let coord = Coordinator::start(Arc::new(GoldenBackend::new()), cfg).unwrap();
    let spec = coord.specs()[0];
    let ids: Vec<u64> = (0..4).map(|_| coord.open_session(&spec).unwrap().id).collect();
    let err = coord.open_session(&spec).unwrap_err();
    assert_eq!(err.code, ErrorCode::Overloaded, "{err}");
    assert!(err.message.contains("session table full"), "{err}");
    // Closing one frees a slot immediately.
    coord.session_close_blocking(ids[0]).unwrap();
    let info = coord.open_session(&spec).unwrap();
    for id in ids.into_iter().skip(1).chain([info.id]) {
        coord.session_close_blocking(id).unwrap();
    }
    assert_eq!(coord.sessions_open(), 0);
    coord.shutdown();
}

#[test]
fn interleaved_hw_sessions_stay_bit_exact_vs_cold_replay() {
    // Several sessions over two specs, pulsed interleaved with ragged
    // widths on a sharded hw coordinator: per-session state (pipeline
    // registers, delay ledgers) must never bleed across sessions.
    let specs =
        vec![MethodSpec::table1(MethodId::Pwl), MethodSpec::table1(MethodId::TaylorCubic)];
    let cfg =
        CoordinatorConfig { specs: specs.clone(), shards: 2, ..CoordinatorConfig::with_batch(64) };
    let coord = Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap();
    struct Run {
        id: u64,
        spec: MethodSpec,
        input: Vec<i64>,
        got: Vec<i64>,
    }
    let mut runs: Vec<Run> = (0..6)
        .map(|i| {
            let spec = specs[i % specs.len()];
            let info = coord.open_session(&spec).unwrap();
            Run { id: info.id, spec, input: Vec::new(), got: Vec::new() }
        })
        .collect();
    for round in 0..10 {
        for (i, run) in runs.iter_mut().enumerate() {
            // Ragged pulse widths, different per session and round.
            let width = 1 + (i + round * 3) % 9;
            let pulse = raws(&run.spec, &points(width, i * 17 + round * 5));
            run.input.extend_from_slice(&pulse);
            let out = coord.session_pulse_blocking(run.id, pulse).unwrap();
            run.got.extend_from_slice(&out.outputs);
        }
    }
    for run in runs {
        let tail = coord.session_close_blocking(run.id).unwrap();
        let mut got = run.got;
        got.extend_from_slice(&tail.outputs);
        assert_eq!(
            got,
            cold(&run.spec, &run.input),
            "session {} ({}) diverged from its cold replay",
            run.id,
            run.spec
        );
    }
    assert_eq!(coord.sessions_open(), 0);
    coord.shutdown();
}

#[test]
fn warm_stream_matches_the_warm_worker_and_beats_per_batch_refill() {
    let spec = MethodSpec::table1(MethodId::Pwl);
    let p = 32usize;
    let k = 24usize;
    // Reference: the same workload as independent P-element requests on
    // a single-shard hw coordinator. The worker's per-thread stream is
    // itself warm across batches (the seed's streaming-worker win), so
    // its steady-state cycles/element is the best the batch path does.
    let cfg = CoordinatorConfig {
        specs: vec![spec],
        shards: 1,
        ..CoordinatorConfig::with_batch(p)
    };
    let batch_coord = Coordinator::start(Arc::new(HwBackend::new()), cfg.clone()).unwrap();
    for i in 0..k {
        let values: Vec<f32> = points(p, i * p).iter().map(|&x| x as f32).collect();
        batch_coord.evaluate_spec(&spec, values).unwrap();
    }
    let warm_worker = batch_coord.metrics().sim_cycles_per_element();
    assert!(warm_worker > 1.0, "the first batch pays the fill tax, got {warm_worker}");
    batch_coord.shutdown();

    // Streamed: one warm session fed the same elements as k pulses.
    let coord = Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap();
    let info = coord.open_session(&spec).unwrap();
    let mut cycles = 0u64;
    for i in 0..k {
        let out = coord.session_pulse_blocking(info.id, raws(&spec, &points(p, i * p))).unwrap();
        cycles += out.sim_cycles;
    }
    let tail = coord.session_close_blocking(info.id).unwrap();
    cycles += tail.sim_cycles;
    let stream_cpe = cycles as f64 / (k * p) as f64;
    // Sessions are never worse than the warm batch worker (here the
    // cycle ledgers agree exactly: one fill per session vs one fill
    // per worker thread)…
    assert!(
        stream_cpe <= warm_worker,
        "warm session ({stream_cpe} cycles/element) must not lose to the \
         warm batch worker ({warm_worker})"
    );
    // …and strictly beat a per-batch re-fill substrate, which would
    // pay the pipeline depth again on every P-element pulse.
    let depth = info.delay as f64 + 1.0;
    let refill = (depth + p as f64 - 1.0) / p as f64;
    assert!(
        stream_cpe < refill,
        "warm session ({stream_cpe} cycles/element) must beat per-batch \
         re-fill ({refill} cycles/element)"
    );
    // The session pays the depth exactly once over its k·P elements.
    let expected = (depth + (k * p) as f64 - 1.0) / (k * p) as f64;
    assert!((stream_cpe - expected).abs() < 1e-12, "got {stream_cpe}, want {expected}");
    coord.shutdown();
}
